//! Intra-op parallelism solver (§5.1): minimize Σ S_nᵀ(C_n + B_n +
//! Σ_p R(p, S_p, n)) subject to Σ S_nᵀ M_n ≤ budget  — Eq. (1).
//!
//! Exact branch-and-bound handles small graphs (and validates the scalable
//! path in tests); production solves use beam search under a Lagrangian
//! sweep of the memory constraint, plus simulated-annealing refinement.

pub mod ilp;
pub mod sgraph;

use crate::util::rng::Rng;

pub use ilp::{solve_ilp, solve_ilp_detailed, IlpOpts, IlpReport};
pub use sgraph::{Edge, SolverGraph};

#[derive(Debug, Clone)]
pub struct Solution {
    /// Chosen strategy index per solver node.
    pub choice: Vec<usize>,
    /// Total per-iteration time (compute + comm + resharding), seconds.
    pub time: f64,
    /// Σ per-device memory of the chosen strategies, bytes.
    pub mem: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct SolveOpts {
    pub beam_width: usize,
    pub anneal_iters: usize,
    pub lagrange_iters: usize,
    pub seed: u64,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts {
            beam_width: 64,
            anneal_iters: 4000,
            lagrange_iters: 12,
            seed: 0xC0FFEE,
        }
    }
}

/// Evaluate a full assignment. Per-strategy local sums come from the
/// arrays precomputed at [`SolverGraph::build`] time instead of being
/// re-derived from the strategy structs on every call.
pub fn evaluate(sg: &SolverGraph, choice: &[usize]) -> (f64, f64) {
    let mut time = 0.0;
    let mut mem = 0.0;
    for i in 0..sg.len() {
        time += sg.strat_time[i][choice[i]];
        mem += sg.strat_mem[i][choice[i]];
    }
    for e in &sg.edges {
        time += e.cost(choice[e.from], choice[e.to]);
    }
    (time, mem)
}

/// Exact branch-and-bound (reference solver; exponential worst case —
/// call only on small graphs).
pub fn solve_exact(sg: &SolverGraph, budget: f64) -> Option<Solution> {
    let n = sg.len();
    // per-node lower bounds on remaining time and memory
    let min_time: Vec<f64> = sg
        .strat_time
        .iter()
        .map(|t| t.iter().copied().fold(f64::INFINITY, f64::min))
        .collect();
    let min_mem = sg.min_mem();
    let mut suffix_time = vec![0.0; n + 1];
    let mut suffix_mem = vec![0.0; n + 1];
    for i in (0..n).rev() {
        suffix_time[i] = suffix_time[i + 1] + min_time[i];
        suffix_mem[i] = suffix_mem[i + 1] + min_mem[i];
    }
    // incoming edges per node index (from < to in topo construction order)
    let mut in_edges: Vec<Vec<&Edge>> = vec![Vec::new(); n];
    for e in &sg.edges {
        if e.from < e.to {
            in_edges[e.to].push(e);
        } else {
            in_edges[e.from].push(e); // defensive; shouldn't happen
        }
    }

    let mut best: Option<Solution> = None;
    let mut choice = vec![0usize; n];

    fn rec(
        sg: &SolverGraph,
        in_edges: &[Vec<&Edge>],
        suffix_time: &[f64],
        suffix_mem: &[f64],
        budget: f64,
        i: usize,
        time: f64,
        mem: f64,
        choice: &mut Vec<usize>,
        best: &mut Option<Solution>,
    ) {
        if let Some(b) = best {
            if time + suffix_time[i] >= b.time {
                return;
            }
        }
        if mem + suffix_mem[i] > budget {
            return;
        }
        if i == sg.len() {
            let sol = Solution { choice: choice.clone(), time, mem };
            if best.as_ref().map(|b| sol.time < b.time).unwrap_or(true) {
                *best = Some(sol);
            }
            return;
        }
        // order strategies by local cost for better pruning
        let mut order: Vec<usize> =
            (0..sg.sets[i].strategies.len()).collect();
        // keep the original pruning key (compute + comm, grad excluded)
        // so tie-broken optima match the pre-refactor explorer
        order.sort_by(|&a, &b| {
            let sa = &sg.sets[i].strategies[a];
            let sb = &sg.sets[i].strategies[b];
            (sa.compute_time + sa.comm_time)
                .partial_cmp(&(sb.compute_time + sb.comm_time))
                .unwrap()
        });
        for s in order {
            choice[i] = s;
            let mut t = time + sg.strat_time[i][s];
            for e in &in_edges[i] {
                t += e.cost(choice[e.from], s);
            }
            rec(
                sg, in_edges, suffix_time, suffix_mem, budget, i + 1, t,
                mem + sg.strat_mem[i][s], choice, best,
            );
        }
    }

    rec(
        sg, &in_edges, &suffix_time, &suffix_mem, budget, 0, 0.0, 0.0,
        &mut choice, &mut best,
    );
    best
}

/// Beam search minimizing time + λ·mem over *compute* nodes in topo
/// order. Placeholder nodes (params/inputs/consts) are eliminated from
/// the search: they carry no compute and typically one consumer edge, so
/// their best strategy is chosen greedily once consumers are fixed —
/// without this the beam spends its width permuting parameter layouts
/// before any differentiating edge cost appears.
fn beam(sg: &SolverGraph, lambda: f64, width: usize) -> Solution {
    let n = sg.len();
    let is_free: Vec<bool> = sg
        .strat_time
        .iter()
        .map(|t| t.iter().all(|&x| x == 0.0) && t.len() > 1)
        .collect();
    let order: Vec<usize> = (0..n).filter(|&i| !is_free[i]).collect();
    let pos: Vec<Option<usize>> = {
        let mut p = vec![None; n];
        for (k, &i) in order.iter().enumerate() {
            p[i] = Some(k);
        }
        p
    };
    // edges between two beam nodes, keyed by the later one
    let mut in_edges: Vec<Vec<&Edge>> = vec![Vec::new(); order.len()];
    for e in &sg.edges {
        if let (Some(pf), Some(pt)) = (pos[e.from], pos[e.to]) {
            in_edges[pf.max(pt)].push(e);
        }
    }

    #[derive(Clone)]
    struct State {
        choice: Vec<usize>,
        time: f64,
        mem: f64,
    }
    let mut states =
        vec![State { choice: Vec::new(), time: 0.0, mem: 0.0 }];
    for (k, &i) in order.iter().enumerate() {
        let mut next: Vec<State> = Vec::with_capacity(
            states.len() * sg.sets[i].strategies.len(),
        );
        for st in &states {
            for si in 0..sg.sets[i].strategies.len() {
                let mut t = st.time + sg.strat_time[i][si];
                for e in &in_edges[k] {
                    let (f, ti) = if pos[e.to] == Some(k) {
                        (st.choice[pos[e.from].unwrap()], si)
                    } else {
                        (si, st.choice[pos[e.to].unwrap()])
                    };
                    t += e.cost(f, ti);
                }
                let mut c = st.choice.clone();
                c.push(si);
                next.push(State {
                    choice: c,
                    time: t,
                    mem: st.mem + sg.strat_mem[i][si],
                });
            }
        }
        next.sort_by(|a, b| {
            (a.time + lambda * a.mem)
                .partial_cmp(&(b.time + lambda * b.mem))
                .unwrap()
        });
        next.truncate(width);
        states = next;
    }
    let best = states.into_iter().next().expect("beam never empty");
    // materialize the full choice vector; placeholders picked greedily
    let mut choice = vec![usize::MAX; n];
    for (k, &i) in order.iter().enumerate() {
        choice[i] = best.choice[k];
    }
    for i in 0..n {
        if choice[i] == usize::MAX {
            choice[i] = 0;
        }
    }
    // greedy placeholder assignment by incident edge cost + λ·mem
    for i in 0..n {
        if !is_free[i] {
            continue;
        }
        let mut best_si = 0;
        let mut best_cost = f64::INFINITY;
        for si in 0..sg.sets[i].strategies.len() {
            let mut c = lambda * sg.strat_mem[i][si];
            for e in &sg.edges {
                if e.from == i {
                    c += e.cost(si, choice[e.to]);
                } else if e.to == i {
                    c += e.cost(choice[e.from], si);
                }
            }
            if c < best_cost {
                best_cost = c;
                best_si = si;
            }
        }
        choice[i] = best_si;
    }
    let (time, mem) = evaluate(sg, &choice);
    let mut sol = Solution { choice, time, mem };
    icm(sg, &mut sol, lambda);
    icm2(sg, &mut sol, lambda);
    sol
}

/// Iterated conditional modes: sweep nodes in order, setting each to the
/// argmin of (local + incident edge costs + λ·mem) with neighbours fixed.
/// Deterministic; converges in a few sweeps; escapes the "chain mismatch"
/// minima single-site annealing gets stuck in when combined with restarts.
fn icm(sg: &SolverGraph, sol: &mut Solution, lambda: f64) {
    let n = sg.len();
    let mut out_edges: Vec<Vec<&Edge>> = vec![Vec::new(); n];
    let mut in_edges: Vec<Vec<&Edge>> = vec![Vec::new(); n];
    for e in &sg.edges {
        out_edges[e.from].push(e);
        in_edges[e.to].push(e);
    }
    for _sweep in 0..24 {
        let mut changed = false;
        for i in 0..n {
            let cur = sol.choice[i];
            let mut best_si = cur;
            let mut best_cost = f64::INFINITY;
            for si in 0..sg.sets[i].strategies.len() {
                let mut c = sg.strat_time[i][si]
                    + lambda * sg.strat_mem[i][si];
                for e in &in_edges[i] {
                    c += e.cost(sol.choice[e.from], si);
                }
                for e in &out_edges[i] {
                    c += e.cost(si, sol.choice[e.to]);
                }
                if c < best_cost {
                    best_cost = c;
                    best_si = si;
                }
            }
            if best_si != cur {
                sol.choice[i] = best_si;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let (t, m) = evaluate(sg, &sol.choice);
    sol.time = t;
    sol.mem = m;
}

/// Pairwise ICM over edges: jointly reassign both endpoints of each edge
/// (captures coupled moves like "flip fc1 column-parallel + fc2
/// row-parallel together" that single-site sweeps cannot make).
fn icm2(sg: &SolverGraph, sol: &mut Solution, lambda: f64) {
    let n = sg.len();
    let mut incident: Vec<Vec<&Edge>> = vec![Vec::new(); n];
    for e in &sg.edges {
        incident[e.from].push(e);
        incident[e.to].push(e);
    }
    let local = |i: usize, si: usize| {
        sg.strat_time[i][si] + lambda * sg.strat_mem[i][si]
    };
    for _sweep in 0..8 {
        let mut changed = false;
        for e0 in &sg.edges {
            let (u, v) = (e0.from, e0.to);
            let (cu, cv) = (sol.choice[u], sol.choice[v]);
            // factor the objective: cost(su, sv) = mu[su] + mv[sv] +
            // coupling(su, sv), where mu/mv fold local cost plus every
            // incident edge whose other endpoint is fixed. This turns the
            // O(s_u * s_v * deg) inner loop into O((s_u + s_v) * deg +
            // s_u * s_v) — the perf-pass optimization logged in
            // EXPERIMENTS.md §Perf.
            let nu = sg.sets[u].strategies.len();
            let nv = sg.sets[v].strategies.len();
            let mut mu: Vec<f64> = (0..nu).map(|si| local(u, si)).collect();
            for e in &incident[u] {
                if e.from == u && e.to == v || e.from == v && e.to == u {
                    continue; // handled as coupling
                }
                for (si, m) in mu.iter_mut().enumerate() {
                    *m += if e.from == u {
                        e.cost(si, sol.choice[e.to])
                    } else {
                        e.cost(sol.choice[e.from], si)
                    };
                }
            }
            let mut mv: Vec<f64> = (0..nv).map(|si| local(v, si)).collect();
            for e in &incident[v] {
                if e.from == u && e.to == v || e.from == v && e.to == u {
                    continue;
                }
                for (si, m) in mv.iter_mut().enumerate() {
                    *m += if e.from == v {
                        e.cost(si, sol.choice[e.to])
                    } else {
                        e.cost(sol.choice[e.from], si)
                    };
                }
            }
            // coupling: ALL edges directly connecting u and v
            let couplings: Vec<&&Edge> = incident[u]
                .iter()
                .filter(|e| {
                    (e.from == u && e.to == v) || (e.from == v && e.to == u)
                })
                .collect();
            let mut best = (cu, cv);
            let mut best_cost = f64::INFINITY;
            for (su, mu_s) in mu.iter().enumerate() {
                for (sv, mv_s) in mv.iter().enumerate() {
                    let mut c = mu_s + mv_s;
                    for e in &couplings {
                        c += if e.from == u {
                            e.cost(su, sv)
                        } else {
                            e.cost(sv, su)
                        };
                    }
                    if c < best_cost {
                        best_cost = c;
                        best = (su, sv);
                    }
                }
            }
            if best != (cu, cv) {
                sol.choice[u] = best.0;
                sol.choice[v] = best.1;
                changed = true;
            }
        }
        if !changed {
            break;
        }
        icm(sg, sol, lambda);
    }
    let (t, m) = evaluate(sg, &sol.choice);
    sol.time = t;
    sol.mem = m;
}

/// Single-node random reassignment annealing on the penalized objective.
fn anneal(
    sg: &SolverGraph,
    start: Solution,
    lambda: f64,
    iters: usize,
    seed: u64,
) -> Solution {
    let mut rng = Rng::new(seed);
    let mut cur = start.choice.clone();
    let (mut ct, mut cm) = evaluate(sg, &cur);
    let mut best = Solution { choice: cur.clone(), time: ct, mem: cm };
    let pen = |t: f64, m: f64| t + lambda * m;
    let mut cur_pen = pen(ct, cm);
    let mut best_pen = cur_pen;
    for it in 0..iters {
        let node = rng.below(sg.len());
        let ns = sg.sets[node].strategies.len();
        if ns <= 1 {
            continue;
        }
        let old = cur[node];
        let new = rng.below(ns);
        if new == old {
            continue;
        }
        cur[node] = new;
        let (t, m) = evaluate(sg, &cur);
        let p = pen(t, m);
        let temp = 0.3 * (1.0 - it as f64 / iters as f64) + 1e-9;
        let accept = p < cur_pen
            || rng.f64() < (-(p - cur_pen) / (cur_pen * temp + 1e-30)).exp();
        if accept {
            cur_pen = p;
            ct = t;
            cm = m;
            if p < best_pen {
                best_pen = p;
                best = Solution { choice: cur.clone(), time: ct, mem: cm };
            }
        } else {
            cur[node] = old;
        }
    }
    icm(sg, &mut best, lambda);
    icm2(sg, &mut best, lambda);
    best
}

/// Production solve: Lagrangian bisection on λ around the memory budget,
/// beam + anneal at each λ; returns the best budget-feasible solution.
pub fn solve(sg: &SolverGraph, budget: f64, opts: SolveOpts)
             -> Option<Solution> {
    if sg.is_empty() {
        return Some(Solution { choice: vec![], time: 0.0, mem: 0.0 });
    }
    // infeasible even at minimum memory?
    if sg.min_mem().iter().sum::<f64>() > budget {
        return None;
    }
    let mut best: Option<Solution> = None;
    let consider = |s: Solution, best: &mut Option<Solution>| {
        if s.mem <= budget
            && best.as_ref().map(|b| s.time < b.time).unwrap_or(true)
        {
            *best = Some(s);
        }
    };

    // λ = 0: pure-time optimum (feasible when memory is plentiful)
    let s0 = anneal(
        sg,
        beam(sg, 0.0, opts.beam_width),
        0.0,
        opts.anneal_iters,
        opts.seed,
    );
    let needs_lagrange = s0.mem > budget;
    consider(s0, &mut best);
    if !needs_lagrange {
        return best;
    }

    // bisect λ until the beam lands under budget
    let (mut lo, mut hi) = (0.0f64, 1e-6);
    // grow hi until feasible
    for _ in 0..40 {
        let s = beam(sg, hi, opts.beam_width);
        if s.mem <= budget {
            break;
        }
        hi *= 8.0;
    }
    for it in 0..opts.lagrange_iters {
        let mid = 0.5 * (lo + hi);
        let s = anneal(
            sg,
            beam(sg, mid, opts.beam_width),
            mid,
            opts.anneal_iters / 4,
            opts.seed ^ it as u64,
        );
        if s.mem <= budget {
            hi = mid;
            consider(s, &mut best);
        } else {
            lo = mid;
        }
    }
    // final polish at hi
    let s = anneal(
        sg,
        beam(sg, hi, opts.beam_width),
        hi,
        opts.anneal_iters,
        opts.seed ^ 0xABCD,
    );
    consider(s, &mut best);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::DeviceMesh;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};
    use crate::layout::LayoutManager;
    use crate::sim::DeviceModel;

    fn mesh(shape: &[usize]) -> DeviceMesh {
        let n: usize = shape.iter().product();
        DeviceMesh {
            shape: shape.to_vec(),
            devices: (0..n).collect(),
            axis_alpha: vec![1e-6; shape.len()],
            axis_beta: vec![1e11; shape.len()],
        }
    }

    fn build(g: &crate::graph::Graph, m: &DeviceMesh) -> SolverGraph {
        let lm = LayoutManager::new(m.clone());
        SolverGraph::build(g, m, &DeviceModel::a100_80gb(), &lm)
    }

    #[test]
    fn beam_matches_exact_on_small_graph() {
        let g = mlp(64, &[256, 128, 64, 10]);
        let m = mesh(&[4]);
        let sg = build(&g, &m);
        let budget = 1e12; // unconstrained
        let exact = solve_exact(&sg, budget).unwrap();
        let approx = solve(&sg, budget, SolveOpts::default()).unwrap();
        assert!(
            approx.time <= exact.time * 1.02 + 1e-12,
            "beam {} vs exact {}",
            approx.time,
            exact.time
        );
    }

    #[test]
    fn solution_prefers_parallelism_over_serial() {
        let g = mlp(512, &[4096, 4096, 4096, 10]);
        let m = mesh(&[4]);
        let sg = build(&g, &m);
        let sol = solve(&sg, 1e12, SolveOpts::default()).unwrap();
        // serial everything = every node replicated; solution must beat it
        let serial: Vec<usize> = sg
            .sets
            .iter()
            .map(|s| {
                s.strategies
                    .iter()
                    .position(|st| {
                        st.out_spec.used_axes().is_empty()
                            && st
                                .in_specs
                                .iter()
                                .all(|i| i.used_axes().is_empty())
                    })
                    .unwrap_or(0)
            })
            .collect();
        let (serial_time, _) = evaluate(&sg, &serial);
        assert!(
            sol.time < serial_time * 0.6,
            "sol {} vs serial {serial_time}",
            sol.time
        );
    }

    #[test]
    fn memory_budget_is_respected() {
        let g = mlp(64, &[512, 512, 512, 10]);
        let m = mesh(&[4]);
        let sg = build(&g, &m);
        let unconstrained =
            solve(&sg, 1e15, SolveOpts::default()).unwrap();
        // force a tight budget: below the unconstrained answer's memory
        let tight = unconstrained.mem * 0.6;
        let min_possible: f64 = sg.min_mem().iter().sum();
        if min_possible <= tight {
            let sol = solve(&sg, tight, SolveOpts::default()).unwrap();
            assert!(sol.mem <= tight);
            assert!(sol.time >= unconstrained.time * 0.99);
        }
        // impossible budget -> None
        assert!(solve(&sg, min_possible * 0.5, SolveOpts::default())
            .is_none());
    }

    #[test]
    fn gpt2_mini_solves_in_reasonable_time() {
        let g = gpt2(&Gpt2Cfg::mini());
        let m = mesh(&[2, 2]);
        let t0 = std::time::Instant::now();
        let sg = build(&g, &m);
        let sol = solve(
            &sg,
            1e12,
            SolveOpts { anneal_iters: 500, ..Default::default() },
        )
        .unwrap();
        assert!(sol.time > 0.0);
        assert!(
            t0.elapsed().as_secs() < 60,
            "solve took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn evaluate_is_consistent_with_solver_report() {
        let g = mlp(64, &[128, 64, 10]);
        let m = mesh(&[2]);
        let sg = build(&g, &m);
        let sol = solve(&sg, 1e12, SolveOpts::default()).unwrap();
        let (t, mem) = evaluate(&sg, &sol.choice);
        assert!((t - sol.time).abs() < 1e-12);
        assert!((mem - sol.mem).abs() < 1e-6);
    }
}
