//! Solver graph: the merged computation graph the ILP actually optimizes
//! (§5.1's preprocessing).  Computationally-trivial single-input nodes
//! (reshape / transpose / slice) are folded into edges as "spec adapters";
//! scalar-only nodes are dropped; what remains are solver nodes with
//! strategy sets and edges carrying dense resharding-cost matrices
//! R(p, S_p, n).
//!
//! Edge matrices are priced in parallel over [`util::pool`]
//! (crate::util::pool) against a shared `&LayoutManager` — building the
//! graph never needs `&mut` anything, so one build can serve every
//! concurrent solver (see [`api::SolverGraphStore`]
//! (crate::api::SolverGraphStore)).

use crate::cluster::DeviceMesh;
use crate::graph::op::Op;
use crate::graph::{Graph, NodeId};
use crate::layout::LayoutManager;
use crate::sim::DeviceModel;
use crate::spec::{ShardingSpec, SpecId};
use crate::strategy::{generate, propagate_spec, StrategySet};

/// Ops folded into edges (single-input, zero-FLOP).
fn mergeable(op: &Op) -> bool {
    matches!(
        op,
        Op::Reshape { .. } | Op::Transpose { .. } | Op::Slice { .. }
    )
}

/// Solver edge with its dense resharding-cost matrix, stored row-major
/// (`costs[s_from * n_to + s_to]`) — one contiguous allocation instead of
/// the former `Vec<Vec<f64>>` row boxes.
#[derive(Debug, Clone)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    /// Index of the consumer's input this edge feeds.
    pub to_input: usize,
    n_to: usize,
    costs: Vec<f64>,
}

impl Edge {
    pub fn new(
        from: usize,
        to: usize,
        to_input: usize,
        n_to: usize,
        costs: Vec<f64>,
    ) -> Edge {
        debug_assert!(n_to > 0 && costs.len() % n_to == 0);
        Edge { from, to, to_input, n_to, costs }
    }

    /// Resharding seconds for the (producer strategy, consumer strategy)
    /// pair.
    #[inline]
    pub fn cost(&self, s_from: usize, s_to: usize) -> f64 {
        self.costs[s_from * self.n_to + s_to]
    }

    /// Producer-side strategy count (matrix rows).
    pub fn n_from(&self) -> usize {
        self.costs.len() / self.n_to
    }

    /// Consumer-side strategy count (matrix columns / row stride).
    pub fn n_to(&self) -> usize {
        self.n_to
    }
}

/// Flattened-chain description of one edge, built sequentially and priced
/// in parallel.
struct EdgeDesc {
    from_sn: usize,
    to_sn: usize,
    to_input: usize,
    /// The real producer node (after walking back through the chain).
    producer: NodeId,
    /// Trivial adapter chain, in forward order.
    chain: Vec<NodeId>,
    consumer: NodeId,
}

pub struct SolverGraph {
    /// Solver-node -> original anchor node.
    pub anchors: Vec<NodeId>,
    /// Original node -> solver node (usize::MAX for folded/dropped nodes).
    pub solver_of: Vec<usize>,
    pub sets: Vec<StrategySet>,
    pub edges: Vec<Edge>,
    /// Precomputed per-node, per-strategy local time
    /// (compute + correctness comm + grad sync), seconds — the hot sums
    /// `evaluate` and the beam scorer used to recompute on every call.
    pub strat_time: Vec<Vec<f64>>,
    /// Precomputed per-node, per-strategy per-device memory, bytes.
    pub strat_mem: Vec<Vec<f64>>,
}

impl SolverGraph {
    pub fn len(&self) -> usize {
        self.anchors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.anchors.is_empty()
    }

    /// Per-node minimum memory (for infeasibility pruning).
    pub fn min_mem(&self) -> Vec<f64> {
        self.strat_mem
            .iter()
            .map(|m| m.iter().copied().fold(f64::INFINITY, f64::min))
            .collect()
    }

    /// Build from a computation graph: generate strategies for every
    /// solver node, fold trivial chains, and price every edge's
    /// (producer strategy, consumer strategy) resharding with the layout
    /// manager (costs land in its shared cache — §4.3 "solver supports").
    /// Strategy generation and edge pricing both fan out over the thread
    /// pool; `layout` is only read-locked, so a single manager serves
    /// every worker.
    pub fn build(
        g: &Graph,
        mesh: &DeviceMesh,
        dev: &DeviceModel,
        layout: &LayoutManager,
    ) -> SolverGraph {
        let mut anchors = Vec::new();
        let mut solver_of = vec![usize::MAX; g.len()];
        for n in &g.nodes {
            if mergeable(&n.op) || matches!(n.op, Op::Output) {
                continue;
            }
            // scalar-only nodes (e.g. attn scale consts) are kept: they
            // are placeholders with a single replicated strategy — cheap.
            solver_of[n.id] = anchors.len();
            anchors.push(n.id);
        }

        let sets: Vec<StrategySet> = crate::util::pool::parallel_map(
            &anchors,
            |&id| generate(g, id, mesh, dev),
        );

        // walk each solver node's inputs back through trivial chains
        let mut descs = Vec::new();
        for (to_sn, &to_id) in anchors.iter().enumerate() {
            let node = g.node(to_id);
            for (to_input, &inp) in node.inputs.iter().enumerate() {
                // collect the adapter chain (forward order)
                let mut chain: Vec<NodeId> = Vec::new();
                let mut cur = inp;
                while mergeable(&g.node(cur).op) {
                    chain.push(cur);
                    cur = g.node(cur).inputs[0];
                }
                chain.reverse();
                let from_sn = solver_of[cur];
                if from_sn == usize::MAX {
                    continue;
                }
                descs.push(EdgeDesc {
                    from_sn,
                    to_sn,
                    to_input,
                    producer: cur,
                    chain,
                    consumer: to_id,
                });
            }
        }
        let edges: Vec<Edge> =
            crate::util::pool::parallel_map(&descs, |d| {
                let costs = price_edge(
                    g, layout, &sets[d.from_sn], &sets[d.to_sn],
                    d.to_input, d.producer, &d.chain, d.consumer,
                );
                Edge::new(
                    d.from_sn,
                    d.to_sn,
                    d.to_input,
                    sets[d.to_sn].strategies.len(),
                    costs,
                )
            });

        let strat_time: Vec<Vec<f64>> = sets
            .iter()
            .map(|set| {
                set.strategies
                    .iter()
                    .map(|s| s.compute_time + s.comm_time + s.grad_comm)
                    .collect()
            })
            .collect();
        let strat_mem: Vec<Vec<f64>> = sets
            .iter()
            .map(|set| {
                set.strategies.iter().map(|s| s.mem_bytes).collect()
            })
            .collect();

        SolverGraph { anchors, solver_of, sets, edges, strat_time, strat_mem }
    }
}

/// Price one edge's dense matrix, row-major over (producer strategy,
/// consumer strategy).
#[allow(clippy::too_many_arguments)]
fn price_edge(
    g: &Graph,
    layout: &LayoutManager,
    from_set: &StrategySet,
    to_set: &StrategySet,
    to_input: usize,
    producer: NodeId,
    chain: &[NodeId],
    consumer: NodeId,
) -> Vec<f64> {
    let consumer_in_meta = {
        let n = g.node(consumer);
        &g.node(n.inputs[to_input]).out
    };
    let prod_meta = &g.node(producer).out;
    let elem = prod_meta.dtype.bytes();

    let n_to = to_set.strategies.len();
    let mut costs = vec![0.0; from_set.strategies.len() * n_to];
    for (si, s) in from_set.strategies.iter().enumerate() {
        // propagate producer's out spec through the trivial chain
        let mut spec: Option<ShardingSpec> =
            Some(s.out_spec.spec().as_ref().clone());
        let mut shape = prod_meta.shape.clone();
        for &t in chain {
            let tn = g.node(t);
            spec = spec.and_then(|sp| {
                propagate_spec(&tn.op, &sp, &shape, &tn.out.shape)
            });
            shape = tn.out.shape.clone();
        }
        let spec_id: Option<SpecId> = spec.map(|sp| sp.id());
        let row = &mut costs[si * n_to..(si + 1) * n_to];
        for (ti, t) in to_set.strategies.iter().enumerate() {
            let want: SpecId = if to_input < t.in_specs.len() {
                t.in_specs[to_input]
            } else {
                // placeholder-ish consumer: no required spec
                continue;
            };
            row[ti] = match spec_id {
                Some(sp) => {
                    layout
                        .convert_ids(
                            sp, want, &consumer_in_meta.shape, elem,
                        )
                        .comm_time
                }
                None => {
                    // sharding broken mid-chain: gather at the producer,
                    // then shard to the consumer's need (shard is free)
                    let repl =
                        SpecId::replicated(prod_meta.shape.len());
                    let gather = layout
                        .convert_ids(
                            s.out_spec, repl, &prod_meta.shape, elem,
                        )
                        .comm_time;
                    let want_r = SpecId::replicated(want.rank());
                    let shard_in = layout
                        .convert_ids(
                            want_r, want, &consumer_in_meta.shape, elem,
                        )
                        .comm_time;
                    gather + shard_in
                }
            };
        }
    }
    costs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};

    fn mesh4() -> DeviceMesh {
        DeviceMesh {
            shape: vec![4],
            devices: (0..4).collect(),
            axis_alpha: vec![1e-6],
            axis_beta: vec![1e11],
        }
    }

    #[test]
    fn mlp_solver_graph_has_no_trivial_nodes() {
        let g = mlp(32, &[128, 64, 10]);
        let lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &lm,
        );
        for &a in &sg.anchors {
            assert!(!mergeable(&g.node(a).op));
        }
        assert!(!sg.edges.is_empty());
    }

    #[test]
    fn gpt2_merges_reshape_transpose_chains() {
        let g = gpt2(&Gpt2Cfg::mini());
        let trivial = g
            .nodes
            .iter()
            .filter(|n| mergeable(&n.op))
            .count();
        assert!(trivial > 10, "gpt2 has many trivial nodes: {trivial}");
        let lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &lm,
        );
        // solver graph is strictly smaller
        assert!(sg.len() + trivial + 1 == g.len());
        // every edge endpoints valid + cost matrices match set sizes
        for e in &sg.edges {
            assert!(e.from < sg.len() && e.to < sg.len());
            assert_eq!(e.n_from(), sg.sets[e.from].strategies.len());
            assert_eq!(e.n_to(), sg.sets[e.to].strategies.len());
        }
        // layout cache should have been populated heavily
        assert!(lm.cache_len() > 10);
    }

    #[test]
    fn edge_costs_zero_for_matching_specs() {
        let g = mlp(32, &[128, 64, 10]);
        let lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &lm,
        );
        // for every edge there must exist at least one zero-cost pair
        for e in &sg.edges {
            let any_zero = (0..e.n_from()).any(|si| {
                (0..e.n_to()).any(|ti| e.cost(si, ti) == 0.0)
            });
            assert!(any_zero, "edge {e:?} has no compatible pair");
        }
    }

    #[test]
    fn precomputed_strategy_arrays_match_the_sets() {
        let g = mlp(32, &[128, 64, 10]);
        let lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &lm,
        );
        for (i, set) in sg.sets.iter().enumerate() {
            assert_eq!(sg.strat_time[i].len(), set.strategies.len());
            for (si, s) in set.strategies.iter().enumerate() {
                assert_eq!(
                    sg.strat_time[i][si],
                    s.compute_time + s.comm_time + s.grad_comm
                );
                assert_eq!(sg.strat_mem[i][si], s.mem_bytes);
            }
        }
    }
}
