//! Solver graph: the merged computation graph the ILP actually optimizes
//! (§5.1's preprocessing).  Computationally-trivial single-input nodes
//! (reshape / transpose / slice) are folded into edges as "spec adapters";
//! scalar-only nodes are dropped; what remains are solver nodes with
//! strategy sets and edges carrying dense resharding-cost matrices
//! R(p, S_p, n).

use crate::cluster::DeviceMesh;
use crate::graph::op::Op;
use crate::graph::{Graph, NodeId};
use crate::layout::LayoutManager;
use crate::sim::DeviceModel;
use crate::spec::ShardingSpec;
use crate::strategy::{generate, propagate_spec, StrategySet};

/// Ops folded into edges (single-input, zero-FLOP).
fn mergeable(op: &Op) -> bool {
    matches!(
        op,
        Op::Reshape { .. } | Op::Transpose { .. } | Op::Slice { .. }
    )
}

#[derive(Debug, Clone)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    /// Index of the consumer's input this edge feeds.
    pub to_input: usize,
    /// cost\[s_from\]\[s_to\] = resharding seconds for that strategy pair.
    pub cost: Vec<Vec<f64>>,
}

pub struct SolverGraph {
    /// Solver-node -> original anchor node.
    pub anchors: Vec<NodeId>,
    /// Original node -> solver node (usize::MAX for folded/dropped nodes).
    pub solver_of: Vec<usize>,
    pub sets: Vec<StrategySet>,
    pub edges: Vec<Edge>,
}

impl SolverGraph {
    pub fn len(&self) -> usize {
        self.anchors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.anchors.is_empty()
    }

    /// Per-node minimum memory (for infeasibility pruning).
    pub fn min_mem(&self) -> Vec<f64> {
        self.sets
            .iter()
            .map(|s| {
                s.strategies
                    .iter()
                    .map(|st| st.mem_bytes)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect()
    }

    /// Build from a computation graph: generate strategies for every
    /// solver node, fold trivial chains, and price every edge's
    /// (producer strategy, consumer strategy) resharding with the layout
    /// manager (costs land in its cache — §4.3 "solver supports").
    pub fn build(
        g: &Graph,
        mesh: &DeviceMesh,
        dev: &DeviceModel,
        layout: &mut LayoutManager,
    ) -> SolverGraph {
        let mut anchors = Vec::new();
        let mut solver_of = vec![usize::MAX; g.len()];
        for n in &g.nodes {
            if mergeable(&n.op) || matches!(n.op, Op::Output) {
                continue;
            }
            // scalar-only nodes (e.g. attn scale consts) are kept: they
            // are placeholders with a single replicated strategy — cheap.
            solver_of[n.id] = anchors.len();
            anchors.push(n.id);
        }

        let sets: Vec<StrategySet> = crate::util::pool::parallel_map(
            &anchors,
            |&id| generate(g, id, mesh, dev),
        );

        // walk each solver node's inputs back through trivial chains
        let mut edges = Vec::new();
        for (to_sn, &to_id) in anchors.iter().enumerate() {
            let node = g.node(to_id);
            for (to_input, &inp) in node.inputs.iter().enumerate() {
                // collect the adapter chain (forward order)
                let mut chain: Vec<NodeId> = Vec::new();
                let mut cur = inp;
                while mergeable(&g.node(cur).op) {
                    chain.push(cur);
                    cur = g.node(cur).inputs[0];
                }
                chain.reverse();
                let from_sn = solver_of[cur];
                if from_sn == usize::MAX {
                    continue;
                }
                let cost = price_edge(
                    g, mesh, layout, &sets[from_sn], &sets[to_sn],
                    to_input, cur, &chain, to_id,
                );
                edges.push(Edge { from: from_sn, to: to_sn, to_input, cost });
            }
        }

        SolverGraph { anchors, solver_of, sets, edges }
    }
}

#[allow(clippy::too_many_arguments)]
fn price_edge(
    g: &Graph,
    mesh: &DeviceMesh,
    layout: &mut LayoutManager,
    from_set: &StrategySet,
    to_set: &StrategySet,
    to_input: usize,
    producer: NodeId,
    chain: &[NodeId],
    consumer: NodeId,
) -> Vec<Vec<f64>> {
    let consumer_in_meta = {
        let n = g.node(consumer);
        &g.node(n.inputs[to_input]).out
    };
    let prod_meta = &g.node(producer).out;
    let elem = prod_meta.dtype.bytes();

    let mut cost =
        vec![vec![0.0; to_set.strategies.len()]; from_set.strategies.len()];
    for (si, s) in from_set.strategies.iter().enumerate() {
        // propagate producer's out spec through the trivial chain
        let mut spec = Some(s.out_spec.clone());
        let mut shape = prod_meta.shape.clone();
        for &t in chain {
            let tn = g.node(t);
            spec = spec.and_then(|sp| {
                propagate_spec(&tn.op, &sp, &shape, &tn.out.shape)
            });
            shape = tn.out.shape.clone();
        }
        for (ti, t) in to_set.strategies.iter().enumerate() {
            let want: &ShardingSpec = if to_input < t.in_specs.len() {
                &t.in_specs[to_input]
            } else {
                // placeholder-ish consumer: no required spec
                continue;
            };
            cost[si][ti] = match &spec {
                Some(sp) => {
                    layout
                        .convert(sp, want, &consumer_in_meta.shape, elem)
                        .comm_time
                }
                None => {
                    // sharding broken mid-chain: gather at the producer,
                    // then shard to the consumer's need (shard is free)
                    let repl =
                        ShardingSpec::replicated(prod_meta.shape.len());
                    let gather = layout
                        .convert(&s.out_spec, &repl, &prod_meta.shape, elem)
                        .comm_time;
                    let want_r =
                        ShardingSpec::replicated(want.rank());
                    let shard_in = layout
                        .convert(&want_r, want, &consumer_in_meta.shape, elem)
                        .comm_time;
                    gather + shard_in
                }
            };
        }
    }
    let _ = mesh;
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};

    fn mesh4() -> DeviceMesh {
        DeviceMesh {
            shape: vec![4],
            devices: (0..4).collect(),
            axis_alpha: vec![1e-6],
            axis_beta: vec![1e11],
        }
    }

    #[test]
    fn mlp_solver_graph_has_no_trivial_nodes() {
        let g = mlp(32, &[128, 64, 10]);
        let mut lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &mut lm,
        );
        for &a in &sg.anchors {
            assert!(!mergeable(&g.node(a).op));
        }
        assert!(!sg.edges.is_empty());
    }

    #[test]
    fn gpt2_merges_reshape_transpose_chains() {
        let g = gpt2(&Gpt2Cfg::mini());
        let trivial = g
            .nodes
            .iter()
            .filter(|n| mergeable(&n.op))
            .count();
        assert!(trivial > 10, "gpt2 has many trivial nodes: {trivial}");
        let mut lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &mut lm,
        );
        // solver graph is strictly smaller
        assert!(sg.len() + trivial + 1 == g.len());
        // every edge endpoints valid + cost matrices match set sizes
        for e in &sg.edges {
            assert!(e.from < sg.len() && e.to < sg.len());
            assert_eq!(e.cost.len(), sg.sets[e.from].strategies.len());
            assert_eq!(
                e.cost[0].len(),
                sg.sets[e.to].strategies.len()
            );
        }
        // layout cache should have been populated heavily
        assert!(lm.cache_len() > 10);
    }

    #[test]
    fn edge_costs_zero_for_matching_specs() {
        let g = mlp(32, &[128, 64, 10]);
        let mut lm = LayoutManager::new(mesh4());
        let sg = SolverGraph::build(
            &g,
            &mesh4(),
            &DeviceModel::a100_80gb(),
            &mut lm,
        );
        // for every edge there must exist at least one zero-cost pair
        for e in &sg.edges {
            let any_zero = e
                .cost
                .iter()
                .any(|row| row.iter().any(|&c| c == 0.0));
            assert!(any_zero, "edge {e:?} has no compatible pair");
        }
    }
}
