//! Chrome-trace / Perfetto JSON exporters.
//!
//! Both converters emit the `traceEvents` array format that Perfetto
//! and `chrome://tracing` load directly: `ph:"X"` complete events with
//! microsecond `ts`/`dur`, `ph:"C"` counter samples for the memory
//! track, and `ph:"M"` metadata naming the process/thread rows.
//!
//! - [`spans_to_chrome`]: recorded planner spans ([`obs::trace`]
//!   (super::trace)). `pid` is the request id (concurrent daemon
//!   requests become separate process tracks), `tid` the pool worker.
//! - [`sim_trace_to_chrome`]: a simulated [`SimTrace`] timeline.
//!   `pid` 0 is the simulated step, `tid` the device index; compute /
//!   comm / recompute segments keep their kinds as categories, and each
//!   device gets a `memory-dev<i>` counter track from the ledger.
//!
//! Output is deterministic for a given input (events in device/time
//! order, canonical JSON writer), which is what lets the golden
//! fixture pin the `SimTrace` conversion byte-for-byte.

use crate::sim::SimTrace;
use crate::util::json::{arr, num, obj, s, Json};

use super::trace::SpanRec;

fn meta(name: &str, pid: u64, tid: Option<u64>, label: &str) -> Json {
    let mut pairs = vec![
        ("name", s(name)),
        ("ph", s("M")),
        ("pid", num(pid as f64)),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", num(t as f64)));
    }
    pairs.push(("args", obj(vec![("name", s(label))])));
    obj(pairs)
}

/// Recorded planner spans -> Chrome-trace JSON.
pub fn spans_to_chrome(spans: &[SpanRec]) -> Json {
    let mut spans: Vec<&SpanRec> = spans.iter().collect();
    spans.sort_by(|a, b| {
        a.start_us
            .partial_cmp(&b.start_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    let mut events = Vec::new();
    let mut pids: Vec<u64> = spans.iter().map(|sp| sp.request).collect();
    pids.sort_unstable();
    pids.dedup();
    for pid in &pids {
        events.push(meta(
            "process_name",
            *pid,
            None,
            &format!("request {pid}"),
        ));
    }
    let mut tids: Vec<(u64, u64)> =
        spans.iter().map(|sp| (sp.request, sp.tid)).collect();
    tids.sort_unstable();
    tids.dedup();
    for (pid, tid) in &tids {
        events.push(meta(
            "thread_name",
            *pid,
            Some(*tid),
            &format!("worker {tid}"),
        ));
    }
    for sp in &spans {
        let mut args: Vec<(&str, Json)> =
            vec![("span_id", num(sp.id as f64))];
        if let Some(p) = sp.parent {
            args.push(("parent", num(p as f64)));
        }
        for (k, v) in &sp.args {
            args.push((k.as_str(), v.clone()));
        }
        events.push(obj(vec![
            ("name", s(&sp.name)),
            ("cat", s(sp.cat)),
            ("ph", s("X")),
            ("ts", num(sp.start_us)),
            ("dur", num(sp.dur_us)),
            ("pid", num(sp.request as f64)),
            ("tid", num(sp.tid as f64)),
            ("args", obj(args)),
        ]));
    }
    obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
    ])
}

/// A simulated timeline -> Chrome-trace JSON (per-device event tracks
/// plus a per-device resident-memory counter track).
pub fn sim_trace_to_chrome(trace: &SimTrace) -> Json {
    let mut events = Vec::new();
    events.push(meta("process_name", 0, None, "simulated step"));
    for d in &trace.devices {
        events.push(meta(
            "thread_name",
            0,
            Some(d.device as u64),
            &format!("device {}", d.device),
        ));
    }
    for d in &trace.devices {
        for e in &d.events {
            events.push(obj(vec![
                ("name", s(&e.label)),
                ("cat", s(e.kind.name())),
                ("ph", s("X")),
                ("ts", num(e.t0 * 1e6)),
                ("dur", num((e.t1 - e.t0) * 1e6)),
                ("pid", num(0.0)),
                ("tid", num(d.device as f64)),
                ("args", obj(vec![("mem", num(e.mem))])),
            ]));
            events.push(obj(vec![
                ("name", s(&format!("memory-dev{}", d.device))),
                ("ph", s("C")),
                ("ts", num(e.t1 * 1e6)),
                ("pid", num(0.0)),
                ("tid", num(d.device as f64)),
                ("args", obj(vec![("bytes", num(e.mem))])),
            ]));
        }
    }
    obj(vec![
        ("traceEvents", arr(events)),
        ("displayTimeUnit", s("ms")),
        (
            "otherData",
            obj(vec![
                ("step_time_us", num(trace.step_time * 1e6)),
                ("peak_mem", num(trace.peak_mem)),
                (
                    "mesh_shape",
                    arr(trace
                        .mesh_shape
                        .iter()
                        .map(|&x| num(x as f64))
                        .collect()),
                ),
            ]),
        ),
    ])
}

/// Max `ts + dur` over complete events, microseconds — the span-total
/// the acceptance test pins against the `SimTrace` step time.
pub fn span_end_us(chrome: &Json) -> f64 {
    let mut max = 0.0f64;
    if let Some(events) = chrome.get("traceEvents").as_arr() {
        for e in events {
            if e.get("ph").as_str() != Some("X") {
                continue;
            }
            let end = e.get("ts").as_f64().unwrap_or(0.0)
                + e.get("dur").as_f64().unwrap_or(0.0);
            max = max.max(end);
        }
    }
    max
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::{DeviceTimeline, EventKind, TraceEvent};

    fn two_device_trace() -> SimTrace {
        SimTrace {
            mesh_shape: vec![2],
            analytic: false,
            step_time: 0.5,
            peak_mem: 2048.0,
            param_mem: 512.0,
            compute_time: 0.4,
            comm_time: 0.1,
            recompute_time: 0.0,
            exposed_grad_time: 0.0,
            devices: vec![
                DeviceTimeline {
                    device: 0,
                    peak_mem: 2048.0,
                    events: vec![
                        TraceEvent {
                            kind: EventKind::FwdCompute,
                            label: "fwd s0".into(),
                            t0: 0.0,
                            t1: 0.2,
                            mem: 1024.0,
                        },
                        TraceEvent {
                            kind: EventKind::BwdCompute,
                            label: "bwd s0".into(),
                            t0: 0.2,
                            t1: 0.5,
                            mem: 512.0,
                        },
                    ],
                },
                DeviceTimeline {
                    device: 1,
                    peak_mem: 1024.0,
                    events: vec![TraceEvent {
                        kind: EventKind::Comm,
                        label: "p2p".into(),
                        t0: 0.1,
                        t1: 0.3,
                        mem: 256.0,
                    }],
                },
            ],
        }
    }

    #[test]
    fn sim_conversion_is_deterministic_and_complete() {
        let t = two_device_trace();
        let a = sim_trace_to_chrome(&t).to_string();
        let b = sim_trace_to_chrome(&t).to_string();
        assert_eq!(a, b);
        let v = sim_trace_to_chrome(&t);
        let events = v.get("traceEvents").as_arr().unwrap();
        // 1 process + 2 thread metadata, 3 X events, 3 C samples
        assert_eq!(events.len(), 9);
        let x_count = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .count();
        assert_eq!(x_count, 3);
        let c_count = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("C"))
            .count();
        assert_eq!(c_count, 3);
    }

    #[test]
    fn span_totals_agree_with_the_step_time() {
        let t = two_device_trace();
        let v = sim_trace_to_chrome(&t);
        let end = span_end_us(&v);
        assert!(
            (end - t.step_time * 1e6).abs() < 1.0,
            "span end {end} us vs step {} us",
            t.step_time * 1e6
        );
    }

    #[test]
    fn planner_spans_become_request_scoped_tracks() {
        let spans = vec![
            SpanRec {
                id: 1,
                parent: None,
                request: 1,
                name: "plan".into(),
                cat: "service",
                start_us: 0.0,
                dur_us: 100.0,
                tid: 1,
                args: vec![],
            },
            SpanRec {
                id: 2,
                parent: Some(1),
                request: 1,
                name: "solve-sharding".into(),
                cat: "planner",
                start_us: 10.0,
                dur_us: 50.0,
                tid: 2,
                args: vec![(
                    "shape".into(),
                    crate::util::json::s("[2,2]"),
                )],
            },
        ];
        let v = spans_to_chrome(&spans);
        let events = v.get("traceEvents").as_arr().unwrap();
        // 1 process meta + 2 thread metas + 2 X events
        assert_eq!(events.len(), 5);
        let x: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .collect();
        assert_eq!(x.len(), 2);
        assert_eq!(x[0].get("pid").as_f64(), Some(1.0));
        assert_eq!(x[1].get("args").get("parent").as_f64(), Some(1.0));
    }
}
