//! Process-wide hierarchical span recorder.
//!
//! A [`SpanGuard`] opens a span on creation and records it on drop.
//! Nesting works two ways:
//!
//! - **Same thread:** a thread-local stack; a span opened while another
//!   is live parents under it and shares its request id.
//! - **Across the pool:** opening a span installs a
//!   [`pool`](crate::util::pool) keyed slot carrying `(span id, request
//!   id)`; `parallel_map` clones slots into its workers, so a span
//!   opened on a worker thread (pipeline cell, portfolio entrant,
//!   batch request) parents under the span that was live when the
//!   fan-out started — exactly how `ProgressHub` crosses the pool.
//!
//! A span opened with an empty stack and no inherited slot starts a new
//! *request* (its id becomes the Perfetto `pid`), so concurrent daemon
//! requests separate into distinct process tracks.
//!
//! The recorder is **disabled by default**: `span()` then costs one
//! relaxed atomic load and allocates nothing. When enabled, the only
//! shared write is a single `Mutex<Vec<_>>` push per finished span.

use std::any::TypeId;
use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;
use crate::util::pool;

/// One finished span, as drained by [`take`].
#[derive(Debug, Clone)]
pub struct SpanRec {
    pub id: u64,
    /// Enclosing span, if any (same request).
    pub parent: Option<u64>,
    /// Root-span id of the request this span belongs to.
    pub request: u64,
    pub name: String,
    /// Coarse category (`planner`, `solve`, `pp`, `io`, `serve`, ...).
    pub cat: &'static str,
    /// Start, microseconds since the tracer's epoch.
    pub start_us: f64,
    pub dur_us: f64,
    /// Small per-thread integer (1 = first thread seen), the Perfetto
    /// `tid`.
    pub tid: u64,
    /// Free-form span arguments (B&B node counts, mesh shapes, ...).
    pub args: Vec<(String, Json)>,
}

/// The `(parent, request)` pair propagated into pool workers.
struct TraceCtx {
    parent: u64,
    request: u64,
}

struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    next_id: AtomicU64,
    spans: Mutex<Vec<SpanRec>>,
}

fn tracer() -> &'static Tracer {
    static GLOBAL: OnceLock<Tracer> = OnceLock::new();
    GLOBAL.get_or_init(|| Tracer {
        enabled: AtomicBool::new(false),
        epoch: Instant::now(),
        next_id: AtomicU64::new(1),
        spans: Mutex::new(Vec::new()),
    })
}

thread_local! {
    /// Live spans on this thread: `(span id, request id)`, innermost
    /// last.
    static STACK: RefCell<Vec<(u64, u64)>> = const { RefCell::new(Vec::new()) };
    /// Lazily-assigned small thread number for the Perfetto `tid`.
    static TID: Cell<u64> = const { Cell::new(0) };
}

fn worker_tid() -> u64 {
    TID.with(|t| {
        let mut v = t.get();
        if v == 0 {
            static NEXT: AtomicU64 = AtomicU64::new(1);
            v = NEXT.fetch_add(1, Ordering::Relaxed);
            t.set(v);
        }
        v
    })
}

/// Start recording (clears anything recorded before).
pub fn enable() {
    let t = tracer();
    t.spans.lock().unwrap().clear();
    t.enabled.store(true, Ordering::Relaxed);
}

/// Stop recording; already-open spans still record on drop.
pub fn disable() {
    tracer().enabled.store(false, Ordering::Relaxed);
}

/// True when the recorder is collecting spans.
pub fn enabled() -> bool {
    tracer().enabled.load(Ordering::Relaxed)
}

/// Drain every recorded span (oldest first is not guaranteed; sort by
/// `start_us` for display).
pub fn take() -> Vec<SpanRec> {
    std::mem::take(&mut *tracer().spans.lock().unwrap())
}

/// Open a span. Returns an inert guard (no allocation, no bookkeeping)
/// while the tracer is disabled.
pub fn span(name: impl Into<String>, cat: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    let t = tracer();
    let id = t.next_id.fetch_add(1, Ordering::Relaxed);
    let (parent, request) = STACK.with(|s| match s.borrow().last() {
        Some(&(pid, req)) => (Some(pid), req),
        None => match pool::current_slot(TypeId::of::<TraceCtx>())
            .and_then(|c| c.downcast::<TraceCtx>().ok())
        {
            Some(ctx) => (Some(ctx.parent), ctx.request),
            // no enclosing span anywhere: this span IS the request
            None => (None, id),
        },
    });
    STACK.with(|s| s.borrow_mut().push((id, request)));
    let prev_slot = pool::install_slot(
        TypeId::of::<TraceCtx>(),
        Some(Arc::new(TraceCtx { parent: id, request })),
    );
    SpanGuard {
        live: Some(LiveSpan {
            id,
            parent,
            request,
            name: name.into(),
            cat,
            start_us: t.epoch.elapsed().as_secs_f64() * 1e6,
            t0: Instant::now(),
            prev_slot,
            args: Vec::new(),
        }),
    }
}

struct LiveSpan {
    id: u64,
    parent: Option<u64>,
    request: u64,
    name: String,
    cat: &'static str,
    start_us: f64,
    t0: Instant,
    prev_slot: Option<pool::Ctx>,
    args: Vec<(String, Json)>,
}

/// RAII handle for an open span; records the span when dropped.
#[must_use = "dropping the guard immediately closes the span"]
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

impl SpanGuard {
    /// Attach an argument shown in the trace viewer's span details.
    pub fn arg(&mut self, key: &str, value: Json) {
        if let Some(live) = &mut self.live {
            live.args.push((key.to_string(), value));
        }
    }

    /// The request id this span belongs to (None when inert).
    pub fn request(&self) -> Option<u64> {
        self.live.as_ref().map(|l| l.request)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(live) = self.live.take() else { return };
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
        pool::install_slot(
            TypeId::of::<TraceCtx>(),
            live.prev_slot.clone(),
        );
        let rec = SpanRec {
            id: live.id,
            parent: live.parent,
            request: live.request,
            name: live.name.clone(),
            cat: live.cat,
            start_us: live.start_us,
            dur_us: live.t0.elapsed().as_secs_f64() * 1e6,
            tid: worker_tid(),
            args: live.args.clone(),
        };
        tracer().spans.lock().unwrap().push(rec);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is process-global; serialize tests that flip it.
    fn lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _l = lock();
        disable();
        {
            let mut sp = span("noop", "test");
            sp.arg("k", crate::util::json::num(1.0));
            assert!(sp.request().is_none());
        }
        assert!(take().is_empty());
    }

    #[test]
    fn same_thread_spans_nest_and_share_a_request() {
        let _l = lock();
        enable();
        {
            let root = span("root", "test");
            let root_req = root.request().unwrap();
            {
                let child = span("child", "test");
                assert_eq!(child.request(), Some(root_req));
            }
        }
        disable();
        let spans = take();
        assert_eq!(spans.len(), 2);
        let child =
            spans.iter().find(|s| s.name == "child").unwrap();
        let root = spans.iter().find(|s| s.name == "root").unwrap();
        assert_eq!(child.parent, Some(root.id));
        assert_eq!(child.request, root.request);
        assert_eq!(root.parent, None);
        assert_eq!(root.request, root.id);
    }

    #[test]
    fn pool_worker_spans_parent_under_the_spawning_request() {
        let _l = lock();
        enable();
        let root_req = {
            let root = span("fanout-root", "test");
            let items: Vec<usize> = (0..16).collect();
            pool::parallel_map(&items, |i| {
                let mut sp = span(format!("cell-{i}"), "test");
                sp.arg("index", crate::util::json::num(*i as f64));
            });
            root.request().unwrap()
        };
        disable();
        let spans = take();
        let cells: Vec<&SpanRec> = spans
            .iter()
            .filter(|s| s.name.starts_with("cell-"))
            .collect();
        assert_eq!(cells.len(), 16);
        for c in &cells {
            // the fan-out root is the request root, so worker spans
            // parent directly under it and inherit its request id
            assert_eq!(
                c.parent,
                Some(root_req),
                "worker span {} must parent under the fan-out root",
                c.name
            );
            assert_eq!(c.request, root_req);
        }
        // the guard restored the slot: a fresh span is a fresh request
        enable();
        drop(span("fresh", "test"));
        disable();
        let fresh = take();
        assert_eq!(fresh.len(), 1);
        assert!(fresh[0].parent.is_none());
        assert_ne!(fresh[0].request, root_req);
    }
}
