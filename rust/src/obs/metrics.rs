//! Atomic counter / gauge / histogram registry with Prometheus text
//! exposition (`GET /v1/metrics` on the daemon).
//!
//! Series are keyed by metric name plus a sorted label set, so
//! exposition is deterministic. Histograms use fixed log-scale
//! millisecond buckets (1-2-5 decades): latency distributions across
//! endpoints and solver backends stay comparable without per-series
//! configuration.
//!
//! Two feeds keep existing code uninstrumented:
//!
//! - [`record_event`] taps the [`ProgressEvent`] stream (the daemon
//!   calls it once per event, wherever the event was born), turning
//!   stage timings, cache lookups, sgraph builds, and pipeline-cell
//!   outcomes into counters and histograms.
//! - [`sync_cache_stats`] mirrors the service's exact cumulative
//!   [`CacheStats`] counters into gauges at scrape time, so `/v1/
//!   metrics` always agrees with `/v1/cache/stats`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::api::cache::CacheStats;
use crate::api::ProgressEvent;

/// Fixed log-scale latency buckets, milliseconds (`+Inf` is implicit).
pub const BUCKETS_MS: [f64; 13] = [
    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0,
    2000.0, 5000.0,
];

struct Histo {
    /// One slot per bucket plus the trailing `+Inf`.
    counts: Vec<AtomicU64>,
    /// Sum of observations, microseconds (integer keeps it atomic).
    sum_us: AtomicU64,
}

impl Histo {
    fn new() -> Histo {
        Histo {
            counts: (0..=BUCKETS_MS.len())
                .map(|_| AtomicU64::new(0))
                .collect(),
            sum_us: AtomicU64::new(0),
        }
    }

    fn observe_ms(&self, ms: f64) {
        let slot = BUCKETS_MS
            .iter()
            .position(|b| ms <= *b)
            .unwrap_or(BUCKETS_MS.len());
        self.counts[slot].fetch_add(1, Ordering::Relaxed);
        self.sum_us
            .fetch_add((ms.max(0.0) * 1e3) as u64, Ordering::Relaxed);
    }
}

/// metric name -> rendered label set -> series.
type Series<T> = Mutex<BTreeMap<String, BTreeMap<String, Arc<T>>>>;

struct Registry {
    counters: Series<AtomicU64>,
    gauges: Series<AtomicU64>,
    histos: Series<Histo>,
}

fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histos: Mutex::new(BTreeMap::new()),
    })
}

fn escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// `{a="b",c="d"}` with labels sorted by key; empty string for none.
fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut ls: Vec<(String, String)> = labels
        .iter()
        .map(|(k, v)| (k.to_string(), escape(v)))
        .collect();
    ls.sort();
    let body = ls
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect::<Vec<_>>()
        .join(",");
    format!("{{{body}}}")
}

/// Splice an extra label (the histogram `le`) into a rendered set.
fn with_label(rendered: &str, key: &str, value: &str) -> String {
    if rendered.is_empty() {
        format!("{{{key}=\"{value}\"}}")
    } else {
        format!(
            "{},{key}=\"{value}\"}}",
            &rendered[..rendered.len() - 1]
        )
    }
}

fn series<T>(
    map: &Series<T>,
    name: &str,
    labels: &[(&str, &str)],
    make: impl FnOnce() -> T,
) -> Arc<T> {
    let key = label_key(labels);
    let mut m = map.lock().unwrap();
    Arc::clone(
        m.entry(name.to_string())
            .or_default()
            .entry(key)
            .or_insert_with(|| Arc::new(make())),
    )
}

/// Add `by` to a counter series.
pub fn inc(name: &str, labels: &[(&str, &str)], by: u64) {
    series(&registry().counters, name, labels, || AtomicU64::new(0))
        .fetch_add(by, Ordering::Relaxed);
}

/// Set a gauge series to `value`.
pub fn gauge_set(name: &str, labels: &[(&str, &str)], value: u64) {
    series(&registry().gauges, name, labels, || AtomicU64::new(0))
        .store(value, Ordering::Relaxed);
}

/// Record one latency observation, milliseconds.
pub fn observe_ms(name: &str, labels: &[(&str, &str)], ms: f64) {
    series(&registry().histos, name, labels, Histo::new)
        .observe_ms(ms);
}

/// Prometheus text exposition of every registered series.
pub fn expose() -> String {
    let r = registry();
    let mut out = String::new();
    for (name, by_label) in r.counters.lock().unwrap().iter() {
        let _ = writeln!(out, "# TYPE {name} counter");
        for (labels, v) in by_label {
            let _ = writeln!(
                out,
                "{name}{labels} {}",
                v.load(Ordering::Relaxed)
            );
        }
    }
    for (name, by_label) in r.gauges.lock().unwrap().iter() {
        let _ = writeln!(out, "# TYPE {name} gauge");
        for (labels, v) in by_label {
            let _ = writeln!(
                out,
                "{name}{labels} {}",
                v.load(Ordering::Relaxed)
            );
        }
    }
    for (name, by_label) in r.histos.lock().unwrap().iter() {
        let _ = writeln!(out, "# TYPE {name} histogram");
        for (labels, h) in by_label {
            let mut cum = 0u64;
            for (i, b) in BUCKETS_MS.iter().enumerate() {
                cum += h.counts[i].load(Ordering::Relaxed);
                let _ = writeln!(
                    out,
                    "{name}_bucket{} {cum}",
                    with_label(labels, "le", &format!("{b}"))
                );
            }
            cum += h.counts[BUCKETS_MS.len()].load(Ordering::Relaxed);
            let _ = writeln!(
                out,
                "{name}_bucket{} {cum}",
                with_label(labels, "le", "+Inf")
            );
            let _ = writeln!(
                out,
                "{name}_sum{labels} {}",
                h.sum_us.load(Ordering::Relaxed) as f64 / 1e3
            );
            let _ = writeln!(out, "{name}_count{labels} {cum}");
        }
    }
    out
}

/// The `ProgressEvent` tap: one call per event turns the existing
/// emission points into metrics with no second instrumentation pass.
pub fn record_event(ev: &ProgressEvent) {
    match ev {
        ProgressEvent::StageDone { stage, ms } => {
            observe_ms(
                "automap_stage_ms",
                &[("stage", stage.name())],
                *ms,
            );
        }
        ProgressEvent::SgraphBuild { ms, shared, .. } => {
            inc(
                "automap_sgraph_total",
                &[("result", if *shared { "shared" } else { "built" })],
                1,
            );
            observe_ms("automap_sgraph_wait_ms", &[], *ms);
        }
        ProgressEvent::CacheLookup { source, .. } => {
            inc(
                "automap_cache_lookups_total",
                &[("source", source.name())],
                1,
            );
        }
        ProgressEvent::CacheEvicted { .. } => {
            inc("automap_cache_evictions_total", &[], 1);
        }
        ProgressEvent::RequestDone { source, ms, .. } => {
            inc(
                "automap_requests_total",
                &[("source", source.name())],
                1,
            );
            observe_ms("automap_request_ms", &[], *ms);
        }
        ProgressEvent::PipelineCellSolved { feasible, ms, .. } => {
            inc(
                "automap_pp_cells_total",
                &[(
                    "result",
                    if *feasible { "solved" } else { "infeasible" },
                )],
                1,
            );
            observe_ms("automap_pp_cell_ms", &[], *ms);
        }
        ProgressEvent::CellReused { .. } => {
            inc("automap_pp_cells_total", &[("result", "reused")], 1);
        }
        ProgressEvent::CellRecompiled { ms, .. } => {
            inc(
                "automap_pp_cells_total",
                &[("result", "recompiled")],
                1,
            );
            observe_ms("automap_pp_cell_ms", &[], *ms);
        }
        ProgressEvent::PipelineChosen { schedule, .. } => {
            inc(
                "automap_pp_chosen_total",
                &[("schedule", schedule)],
                1,
            );
        }
        _ => {}
    }
}

/// Mirror the service's exact cumulative cache/registry/cell counters
/// into gauges (called at scrape time by `GET /v1/metrics`).
pub fn sync_cache_stats(st: &CacheStats) {
    for (name, v) in [
        ("automap_cache_memory_hits", st.memory_hits),
        ("automap_cache_disk_hits", st.disk_hits),
        ("automap_cache_partial_resumes", st.partial_resumes),
        ("automap_cache_misses", st.misses),
        ("automap_cache_memory_evictions", st.evictions),
        ("automap_sgraph_builds", st.sgraph_builds),
        ("automap_sgraph_reuses", st.sgraph_reuses),
        ("automap_registry_artifacts", st.registry_artifacts),
        ("automap_registry_bytes", st.registry_bytes),
        ("automap_registry_gc_evictions", st.registry_gc_evictions),
        ("automap_cells_reused", st.cell_reuses),
        ("automap_cells_recompiled", st.cell_recompiles),
    ] {
        gauge_set(name, &[], v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_expose_sorted_labels() {
        inc("test_ctr_total", &[("b", "2"), ("a", "1")], 3);
        inc("test_ctr_total", &[("b", "2"), ("a", "1")], 2);
        gauge_set("test_gauge", &[], 7);
        let text = expose();
        assert!(text.contains("# TYPE test_ctr_total counter"));
        assert!(
            text.contains("test_ctr_total{a=\"1\",b=\"2\"} 5"),
            "{text}"
        );
        assert!(text.contains("# TYPE test_gauge gauge"));
        assert!(text.contains("test_gauge 7"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_consistent() {
        observe_ms("test_hist_ms", &[("k", "v")], 0.3);
        observe_ms("test_hist_ms", &[("k", "v")], 3.0);
        observe_ms("test_hist_ms", &[("k", "v")], 9999.0);
        let text = expose();
        let mut cum_prev = 0u64;
        let mut inf = None;
        let mut count = None;
        let mut sum = None;
        for line in text.lines() {
            if let Some(rest) =
                line.strip_prefix("test_hist_ms_bucket{k=\"v\",le=\"")
            {
                let v: u64 = rest
                    .split_once("\"} ")
                    .unwrap()
                    .1
                    .parse()
                    .unwrap();
                assert!(v >= cum_prev, "buckets must be cumulative");
                cum_prev = v;
                if rest.starts_with("+Inf") {
                    inf = Some(v);
                }
            } else if let Some(rest) =
                line.strip_prefix("test_hist_ms_count{k=\"v\"} ")
            {
                count = Some(rest.parse::<u64>().unwrap());
            } else if let Some(rest) =
                line.strip_prefix("test_hist_ms_sum{k=\"v\"} ")
            {
                sum = Some(rest.parse::<f64>().unwrap());
            }
        }
        assert_eq!(count, Some(3));
        assert_eq!(inf, count, "_count must equal the +Inf bucket");
        let sum = sum.expect("sum line present");
        assert!(
            (sum - (0.3 + 3.0 + 9999.0)).abs() < 0.01,
            "sum {sum} must match the observations"
        );
    }

    #[test]
    fn progress_events_feed_the_bridge() {
        use crate::api::cache::PlanSource;
        record_event(&ProgressEvent::CacheLookup {
            fingerprint: "f".into(),
            source: PlanSource::MemoryHit,
        });
        record_event(&ProgressEvent::CacheLookup {
            fingerprint: "f".into(),
            source: PlanSource::MemoryHit,
        });
        let text = expose();
        let line = text
            .lines()
            .find(|l| {
                l.starts_with(
                    "automap_cache_lookups_total{source=\"memory-hit\"}",
                )
            })
            .expect("bridge counter registered");
        let n: u64 =
            line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(n >= 2);
    }

    #[test]
    fn cache_stats_sync_to_gauges() {
        let st = CacheStats {
            memory_hits: 4,
            disk_hits: 1,
            partial_resumes: 0,
            misses: 2,
            evictions: 0,
            sgraph_builds: 3,
            sgraph_reuses: 5,
            registry_artifacts: 6,
            registry_bytes: 7890,
            registry_gc_evictions: 1,
            cell_reuses: 2,
            cell_recompiles: 9,
        };
        sync_cache_stats(&st);
        let text = expose();
        assert!(text.contains("automap_cache_memory_hits 4"));
        assert!(text.contains("automap_registry_bytes 7890"));
        assert!(text.contains("automap_cells_recompiled 9"));
    }
}
