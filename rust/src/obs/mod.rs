//! Observability: hierarchical span tracing, Perfetto/Chrome-trace
//! export, and a Prometheus-style metrics registry.
//!
//! Three pillars, all std-only:
//!
//! - [`trace`] — a process-wide, lock-light span recorder. Spans nest
//!   via a thread-local stack on one thread and ride the
//!   [`util::pool`](crate::util::pool) keyed-slot propagation across
//!   `parallel_map` fan-outs, so pipeline-cell, portfolio, and batch
//!   worker spans parent under the request that spawned them (the same
//!   mechanism [`ProgressHub`](crate::api::ProgressHub) uses for
//!   events). Disabled by default at near-zero cost; `automap plan
//!   --trace-out x.json` enables it for one run.
//! - [`perfetto`] — converters to Chrome-trace JSON (`traceEvents`):
//!   recorded planner spans (pid = request, tid = pool worker) and
//!   simulated [`SimTrace`](crate::sim::SimTrace) timelines (pid = the
//!   simulated step, tid = device, plus a per-device memory counter
//!   track), both loadable in Perfetto / `chrome://tracing`. Surfaced
//!   as `automap trace <artifact>` and `plan/replan --trace-out`.
//! - [`metrics`] — an atomic counter/gauge/histogram registry with
//!   Prometheus text exposition, fed by a
//!   [`ProgressEvent`](crate::api::ProgressEvent) tap (existing
//!   emission points need no second instrumentation pass) and exposed
//!   by the daemon as `GET /v1/metrics`.

pub mod metrics;
pub mod perfetto;
pub mod trace;
