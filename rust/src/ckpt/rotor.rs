//! Activation-checkpoint solver (§5.2): the *rotor* dynamic program of
//! Herrmann et al. extended with per-stage communication overheads
//! (Theorem 5.1) so it composes with the intra-op parallel plan.

use crate::graph::{Graph, NodeId};
use crate::profiler::cost::node_cost;
use crate::sim::DeviceModel;

/// One linearized stage (a node group from `linearize`).
#[derive(Debug, Clone, Default)]
pub struct Stage {
    pub nodes: Vec<NodeId>,
    /// Forward / backward compute time (u_f, u_b), seconds.
    pub uf: f64,
    pub ub: f64,
    /// Communication overheads of Table 2 (u_fcomm, u_bcomm).
    pub uf_comm: f64,
    pub ub_comm: f64,
    /// Transient memory overheads (o_f, o_b), bytes.
    pub of: f64,
    pub ob: f64,
    /// Boundary activation leaving this stage (ω_a^ℓ), bytes.
    pub wa_out: f64,
    /// Saved intermediate set (ω_ā^ℓ), bytes.
    pub wbar: f64,
}

/// Per-node overrides computed from an intra-op plan (sharded times and
/// memory scale); absent entries fall back to the serial device model.
#[derive(Debug, Clone, Default)]
pub struct NodeTimes {
    pub fwd: Vec<f64>,
    pub bwd: Vec<f64>,
    pub fwd_comm: Vec<f64>,
    pub bwd_comm: Vec<f64>,
    /// Memory division factor per node (sharding factor ≥ 1).
    pub mem_scale: Vec<f64>,
}

/// Backward share of a node's fwd+bwd total under the pipeline's
/// fwd:bwd ≈ 1:2 split. Everything that splits per-node totals
/// ([`NodeTimes::set_split`]) or re-aggregates backward compute (the
/// planner's exposed-grad pricing, the `sim::exec` replayer) must go
/// through this one definition, or predicted and simulated step times
/// drift apart at the ulp level and the differential oracle's
/// `sim ≤ predicted` bound stops being exact.
pub fn bwd_share(total: f64) -> f64 {
    total * 2.0 / 3.0
}

impl NodeTimes {
    /// No-override times for an `n`-node graph (zero cost, scale 1).
    pub fn zeroed(n: usize) -> NodeTimes {
        NodeTimes {
            fwd: vec![0.0; n],
            bwd: vec![0.0; n],
            fwd_comm: vec![0.0; n],
            bwd_comm: vec![0.0; n],
            mem_scale: vec![1.0; n],
        }
    }

    /// Record one node's priced totals using the [`bwd_share`] split
    /// (GEMM-dominated training). The planner's candidate ranking and
    /// the `sim::exec` replayer both price through here, so the
    /// differential oracle always compares like with like.
    pub fn set_split(
        &mut self,
        id: NodeId,
        compute: f64,
        comm: f64,
        mem_scale: f64,
    ) {
        self.fwd[id] = compute / 3.0;
        self.bwd[id] = bwd_share(compute);
        self.fwd_comm[id] = comm / 3.0;
        self.bwd_comm[id] = bwd_share(comm);
        self.mem_scale[id] = mem_scale.max(1.0);
    }
}

/// Build stage costs from the graph, its linearization, and (optionally)
/// the intra-op plan's per-node times.
pub fn build_stages(
    g: &Graph,
    groups: &[Vec<NodeId>],
    dev: &DeviceModel,
    times: Option<&NodeTimes>,
) -> Vec<Stage> {
    let users = g.users();
    let group_of = {
        let mut m = vec![usize::MAX; g.len()];
        for (gi, grp) in groups.iter().enumerate() {
            for &n in grp {
                m[n] = gi;
            }
        }
        m
    };
    groups
        .iter()
        .enumerate()
        .map(|(gi, grp)| {
            let mut st = Stage { nodes: grp.clone(), ..Default::default() };
            for &id in grp {
                let c = node_cost(g, id);
                let n = g.node(id);
                let is_gemm = n.op.compute_intensive();
                let (f, b, fc, bc, scale) = match times {
                    Some(t) => (
                        t.fwd[id],
                        t.bwd[id],
                        t.fwd_comm[id],
                        t.bwd_comm[id],
                        t.mem_scale[id].max(1.0),
                    ),
                    None => (
                        dev.kernel_time(
                            c.fwd_flops,
                            (c.fwd_in + c.fwd_out) as f64,
                            is_gemm,
                        ),
                        dev.kernel_time(
                            c.bwd_flops,
                            (c.fwd_in + c.bwd_out) as f64,
                            is_gemm,
                        ),
                        0.0,
                        0.0,
                        1.0,
                    ),
                };
                st.uf += f;
                st.ub += b;
                st.uf_comm += fc;
                st.ub_comm += bc;
                st.of = st.of.max(c.fwd_tmp as f64 / scale);
                st.ob = st.ob.max(c.bwd_tmp as f64 / scale);
                st.wbar += c.fwd_in as f64 / scale;
                // boundary: outputs consumed outside this group
                if users[id].iter().any(|&u| {
                    group_of.get(u).copied().unwrap_or(usize::MAX) != gi
                }) {
                    let sc = match times {
                        Some(t) => t.mem_scale[id].max(1.0),
                        None => 1.0,
                    };
                    st.wa_out += n.out.bytes() as f64 / sc;
                }
            }
            st
        })
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Dec {
    Infeasible,
    Leaf,
    All,
    Ck(usize),
}

#[derive(Debug, Clone)]
pub struct Block {
    pub start: usize,
    pub end: usize, // inclusive stage range
    pub checkpointed: bool,
}

#[derive(Debug, Clone)]
pub struct RotorSolution {
    /// Total fwd+bwd time including recomputation and comm, seconds.
    pub time: f64,
    /// Top-level checkpoint segmentation (for the code generator).
    pub blocks: Vec<Block>,
    pub budget: f64,
}

impl RotorSolution {
    /// True when `blocks` exactly partition stages `0..n_stages` — the
    /// invariant the code generator and the `sim::exec` replayer rely
    /// on. Deserialized schedules must be checked before use.
    pub fn partitions(&self, n_stages: usize) -> bool {
        let mut next = 0usize;
        for b in &self.blocks {
            if b.start != next || b.end < b.start {
                return false;
            }
            next = b.end + 1;
        }
        next == n_stages
    }
}

pub struct RotorSolver {
    pub stages: Vec<Stage>,
    pub bins: usize,
}

impl RotorSolver {
    pub fn new(stages: Vec<Stage>) -> RotorSolver {
        RotorSolver { stages, bins: 256 }
    }

    /// Time with no checkpointing (keep everything) — the baseline.
    pub fn no_checkpoint_time(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| s.uf + s.uf_comm + s.ub + s.ub_comm)
            .sum()
    }

    /// Memory needed with no checkpointing: all saved sets + worst case.
    pub fn no_checkpoint_mem(&self) -> f64 {
        let saved: f64 = self.stages.iter().map(|s| s.wbar).sum();
        let worst =
            self.stages.iter().map(|s| s.of.max(s.ob)).fold(0.0, f64::max);
        let wd = self.stages.last().map(|s| s.wa_out).unwrap_or(0.0);
        saved + worst + wd
    }

    /// Solve the Theorem-5.1 DP for `budget` bytes of activation memory.
    pub fn solve(&self, budget: f64) -> Option<RotorSolution> {
        let ln = self.stages.len();
        if ln == 0 {
            return Some(RotorSolution {
                time: 0.0,
                blocks: vec![],
                budget,
            });
        }
        let bins = self.bins;
        let q = (budget / bins as f64).max(1.0);
        let u = |bytes: f64| -> usize { (bytes / q).ceil() as usize };

        // boundary in/out, gradient sizes (units)
        let wa_in: Vec<usize> = (0..ln)
            .map(|l| if l == 0 { 0 } else { u(self.stages[l - 1].wa_out) })
            .collect();
        let wa_out: Vec<usize> =
            self.stages.iter().map(|s| u(s.wa_out)).collect();
        let wbar: Vec<usize> =
            self.stages.iter().map(|s| u(s.wbar)).collect();
        let of: Vec<usize> = self.stages.iter().map(|s| u(s.of)).collect();
        let ob: Vec<usize> = self.stages.iter().map(|s| u(s.ob)).collect();
        let wdelta = &wa_out; // δ^ℓ has the shape of a^ℓ

        let uf: Vec<f64> = self
            .stages
            .iter()
            .map(|s| s.uf + s.uf_comm)
            .collect();
        let ub: Vec<f64> = self
            .stages
            .iter()
            .map(|s| s.ub + s.ub_comm)
            .collect();

        // m_all / m_empty thresholds (Eq. 6)
        let m_all = |s: usize, t: usize| -> usize {
            (wdelta[t] + wbar[s] + of[s]).max(wdelta[s] + wbar[s] + ob[s])
        };
        let m_empty = |s: usize, t: usize| -> usize {
            let mut m = wdelta[t] + wa_in[s] + wa_out[s] + of[s];
            for j in s + 1..t {
                m = m.max(wdelta[t] + wa_in[j] + wa_out[j] + of[j]);
            }
            m
        };

        let idx = |s: usize, t: usize, m: usize| (s * ln + t) * (bins + 1) + m;
        let mut c = vec![f64::INFINITY; ln * ln * (bins + 1)];
        let mut dec = vec![Dec::Infeasible; ln * ln * (bins + 1)];

        for s in 0..ln {
            for m in 0..=bins {
                if m >= m_all(s, s) {
                    c[idx(s, s, m)] = uf[s] + ub[s];
                    dec[idx(s, s, m)] = Dec::Leaf;
                }
            }
        }
        for len in 1..ln {
            for s in 0..ln - len {
                let t = s + len;
                let me = m_empty(s, t);
                let ma = m_all(s, t);
                let prefix: Vec<f64> = {
                    // prefix[k] = Σ_{j=s}^{s+k-1} uf[j]
                    let mut p = vec![0.0];
                    for j in s..t {
                        p.push(p.last().unwrap() + uf[j]);
                    }
                    p
                };
                for m in 0..=bins {
                    let mut best = f64::INFINITY;
                    let mut bd = Dec::Infeasible;
                    if m >= me {
                        for sp in s + 1..=t {
                            if wa_in[sp] > m {
                                continue;
                            }
                            let right = c[idx(sp, t, m - wa_in[sp])];
                            let left = c[idx(s, sp - 1, m)];
                            let v = prefix[sp - s] + right + left;
                            if v < best {
                                best = v;
                                bd = Dec::Ck(sp);
                            }
                        }
                    }
                    if m >= ma && wbar[s] <= m {
                        let v = uf[s] + ub[s] + c[idx(s + 1, t, m - wbar[s])];
                        if v < best {
                            best = v;
                            bd = Dec::All;
                        }
                    }
                    c[idx(s, t, m)] = best;
                    dec[idx(s, t, m)] = bd;
                }
            }
        }

        let total = c[idx(0, ln - 1, bins)];
        if !total.is_finite() {
            return None;
        }

        // extract the top-level segmentation
        let mut blocks = Vec::new();
        let (mut s, t, mut m) = (0usize, ln - 1, bins);
        loop {
            match dec[idx(s, t, m)] {
                Dec::Leaf => {
                    blocks.push(Block {
                        start: s,
                        end: t,
                        checkpointed: false,
                    });
                    break;
                }
                Dec::All => {
                    blocks.push(Block {
                        start: s,
                        end: s,
                        checkpointed: false,
                    });
                    if s == t {
                        break;
                    }
                    m -= wbar[s];
                    s += 1;
                }
                Dec::Ck(sp) => {
                    blocks.push(Block {
                        start: s,
                        end: sp - 1,
                        checkpointed: true,
                    });
                    m -= wa_in[sp];
                    s = sp;
                }
                Dec::Infeasible => return None,
            }
            if s == t {
                blocks.push(Block { start: s, end: t, checkpointed: false });
                break;
            }
        }

        Some(RotorSolution { time: total, blocks, budget })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::linearize::{common_nodes, linearize};
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};

    fn solver_for(g: &crate::graph::Graph) -> RotorSolver {
        let groups = linearize(g, &common_nodes(g));
        let stages =
            build_stages(g, &groups, &DeviceModel::a100_80gb(), None);
        RotorSolver::new(stages)
    }

    #[test]
    fn unconstrained_budget_equals_no_checkpoint() {
        let g = mlp(64, &[256; 8].iter().chain(&[10]).cloned()
            .collect::<Vec<_>>());
        let r = solver_for(&g);
        let sol = r.solve(r.no_checkpoint_mem() * 4.0).unwrap();
        assert!(
            (sol.time - r.no_checkpoint_time()).abs()
                / r.no_checkpoint_time()
                < 1e-9,
            "sol {} vs base {}",
            sol.time,
            r.no_checkpoint_time()
        );
        assert!(sol.blocks.iter().all(|b| !b.checkpointed));
    }

    #[test]
    fn tight_budget_forces_recompute_and_costs_time() {
        let g = gpt2(&Gpt2Cfg::mini());
        let r = solver_for(&g);
        let base_mem = r.no_checkpoint_mem();
        let base_time = r.no_checkpoint_time();
        let sol = r.solve(base_mem * 0.45).unwrap();
        assert!(
            sol.time > base_time * 1.01,
            "tight budget must recompute: {} vs {}",
            sol.time,
            base_time
        );
        assert!(sol.blocks.iter().any(|b| b.checkpointed));
    }

    #[test]
    fn time_is_monotone_in_budget() {
        let g = gpt2(&Gpt2Cfg::mini());
        let r = solver_for(&g);
        let base = r.no_checkpoint_mem();
        let mut last = f64::INFINITY;
        for frac in [0.4, 0.55, 0.7, 0.85, 1.2] {
            if let Some(sol) = r.solve(base * frac) {
                assert!(
                    sol.time <= last * (1.0 + 1e-9),
                    "time must not increase with budget (frac {frac})"
                );
                last = sol.time;
            }
        }
    }

    #[test]
    fn impossible_budget_returns_none() {
        let g = gpt2(&Gpt2Cfg::mini());
        let r = solver_for(&g);
        assert!(r.solve(1024.0).is_none()); // 1 KiB: hopeless
    }

    #[test]
    fn blocks_partition_the_chain() {
        let g = gpt2(&Gpt2Cfg::mini());
        let r = solver_for(&g);
        let sol = r.solve(r.no_checkpoint_mem() * 0.5).unwrap();
        let mut next = 0;
        for b in &sol.blocks {
            assert_eq!(b.start, next);
            assert!(b.end >= b.start);
            next = b.end + 1;
        }
        assert_eq!(next, r.stages.len());
        assert!(sol.partitions(r.stages.len()));
        assert!(!sol.partitions(r.stages.len() + 1));
    }

    #[test]
    fn partitions_rejects_gaps_overlaps_and_empty_mismatch() {
        let sol = RotorSolution {
            time: 0.0,
            budget: 0.0,
            blocks: vec![
                Block { start: 0, end: 1, checkpointed: true },
                Block { start: 3, end: 4, checkpointed: false },
            ],
        };
        assert!(!sol.partitions(5), "gap at stage 2 must be rejected");
        let empty =
            RotorSolution { time: 0.0, budget: 0.0, blocks: vec![] };
        assert!(empty.partitions(0));
        assert!(!empty.partitions(1));
    }

    #[test]
    fn comm_overheads_increase_solution_time() {
        let g = gpt2(&Gpt2Cfg::mini());
        let groups = linearize(&g, &common_nodes(&g));
        let dev = DeviceModel::a100_80gb();
        let mut stages = build_stages(&g, &groups, &dev, None);
        let r0 = RotorSolver::new(stages.clone());
        let budget = r0.no_checkpoint_mem() * 0.5;
        let t0 = r0.solve(budget).unwrap().time;
        for s in &mut stages {
            s.uf_comm = s.uf * 0.3;
            s.ub_comm = s.ub * 0.3;
        }
        let r1 = RotorSolver::new(stages);
        let t1 = r1.solve(budget).unwrap().time;
        assert!(t1 > t0 * 1.1, "comm-aware time {t1} vs {t0}");
    }
}
