//! Activation-checkpoint stage (§5.2): graph linearization with common
//! nodes, then the communication-aware rotor DP of Theorem 5.1.

pub mod linearize;
pub mod rotor;

pub use linearize::{common_nodes, linearize};
pub use rotor::{build_stages, bwd_share, Block, NodeTimes, RotorSolution,
                RotorSolver, Stage};
