//! Network linearization (§5.2.2–5.2.4): partition the DAG into a chain
//! of node groups (the rotor solver's stages) using the dependency-pool
//! rule of Algorithm 2, with common-node propagation (Def. 5.3 /
//! Lemma 5.4) so attention masks and friends don't glue everything into
//! one group.

use std::collections::HashMap;

use crate::graph::op::{Op, PlaceholderKind};
use crate::graph::{Graph, NodeId};

/// Common nodes: non-differentiable sources propagated forward
/// (Lemma 5.4): a node is common if its op is non-differentiable, its
/// output dtype carries no gradient, or *all* its parents are common.
pub fn common_nodes(g: &Graph) -> Vec<bool> {
    let mut common = vec![false; g.len()];
    for n in &g.nodes {
        if n.op == Op::Placeholder(PlaceholderKind::Const)
            || (!n.out.dtype.differentiable()
                && !matches!(n.op, Op::Output))
        {
            common[n.id] = true;
            continue;
        }
        if matches!(n.op, Op::Placeholder(_) | Op::Output) {
            continue;
        }
        if !n.inputs.is_empty()
            && n.inputs.iter().all(|&i| common[i])
        {
            common[n.id] = true;
        }
    }
    common
}

/// Is this node invisible to the dependency pool?  Placeholders live in
/// model data; common nodes are excluded per §5.2.3; Output is the sink.
fn excluded(g: &Graph, common: &[bool], id: NodeId) -> bool {
    common[id]
        || matches!(g.node(id).op, Op::Placeholder(_) | Op::Output)
}

/// Algorithm 2: linearize `g` into a chain of stages.
///
/// Walk nodes in topological order maintaining a pool of outstanding
/// dependencies; a node ends the current group when, after removing its
/// parents' dependencies and adding its own, the pool is exactly "this
/// node's own deps" — i.e. nothing earlier is still needed downstream —
/// and none of its children is an in-place op (§5.2.4).
pub fn linearize(g: &Graph, common: &[bool]) -> Vec<Vec<NodeId>> {
    let users = g.users();
    let mut groups: Vec<Vec<NodeId>> = Vec::new();
    let mut current: Vec<NodeId> = Vec::new();
    // deps_pool[node] = #children not yet processed
    let mut deps: HashMap<NodeId, usize> = HashMap::new();

    for n in &g.nodes {
        if excluded(g, common, n.id) {
            continue;
        }
        // remove dependencies this node discharges
        for &p in &n.inputs {
            if let Some(c) = deps.get_mut(&p) {
                *c -= 1;
                if *c == 0 {
                    deps.remove(&p);
                }
            }
        }
        current.push(n.id);
        // register this node's own downstream dependencies
        let n_users = users[n.id]
            .iter()
            .filter(|&&u| !excluded(g, common, u))
            .count();
        if n_users > 0 {
            deps.insert(n.id, n_users);
        }
        // sink check: pool holds at most this node's own entry, and no
        // child is in-place (in-place children must join this group)
        let only_self =
            deps.is_empty() || (deps.len() == 1 && deps.contains_key(&n.id));
        let inplace_child = users[n.id].iter().any(|&u| {
            matches!(
                g.node(u).op,
                Op::EwUnary { in_place: true, .. }
                    | Op::EwBinary { in_place: true, .. }
            )
        });
        if only_self && !inplace_child {
            groups.push(std::mem::take(&mut current));
        }
    }
    if !current.is_empty() {
        groups.push(current);
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{gpt2, mlp, resnet, Gpt2Cfg};
    use crate::graph::{EwUnary, GraphBuilder};

    #[test]
    fn chain_mlp_linearizes_per_layer() {
        let g = mlp(8, &[32, 32, 32, 32, 10]);
        let common = common_nodes(&g);
        let groups = linearize(&g, &common);
        // a pure chain: many small groups, strictly ordered, covering all
        // differentiable op nodes exactly once
        let covered: usize = groups.iter().map(|g| g.len()).sum();
        let expected = g
            .nodes
            .iter()
            .filter(|n| !excluded(&g, &common, n.id))
            .count();
        assert_eq!(covered, expected);
        assert!(groups.len() >= 4, "groups: {}", groups.len());
    }

    #[test]
    fn residual_blocks_group_together() {
        // x -> a -> b -> (x + b): the skip edge must keep a,b in x's group
        let mut b = GraphBuilder::new("res");
        let x = b.input("x", vec![8, 16]);
        let w1 = b.param("w1", vec![16, 16]);
        let h1 = b.matmul("h1", x, w1);
        let w2 = b.param("w2", vec![16, 16]);
        let h2 = b.matmul("h2", h1, w2);
        let r = b.add_t("residual", h1, h2);
        let w3 = b.param("w3", vec![16, 16]);
        let out = b.matmul("out", r, w3);
        b.output(&[out]);
        let g = b.finish().unwrap();
        let groups = linearize(&g, &common_nodes(&g));
        // h2 cannot end a group: h1 is still needed by the skip edge, so
        // h2 and the residual add must share a group
        let gid = |id: NodeId| {
            groups.iter().position(|grp| grp.contains(&id)).unwrap()
        };
        assert_eq!(gid(h2), gid(r));
        assert!(gid(h1) <= gid(h2));
        assert!(gid(out) > gid(r));
    }

    #[test]
    fn gpt2_mask_is_common_and_blocks_split() {
        let g = gpt2(&Gpt2Cfg::mini());
        let common = common_nodes(&g);
        // the causal mask const and the attn scale const are common
        let mask = g.nodes.iter().find(|n| n.name == "causal_mask").unwrap();
        assert!(common[mask.id]);
        // tokens (int input) are non-differentiable -> common
        let tokens = g.nodes.iter().find(|n| n.name == "tokens").unwrap();
        assert!(common[tokens.id]);
        let groups = linearize(&g, &common);
        // without common-node removal GPT-2 collapses into ~1 group; with
        // it we must get at least one group per transformer block
        assert!(
            groups.len() >= Gpt2Cfg::mini().n_layer + 1,
            "got {} groups",
            groups.len()
        );
    }

    #[test]
    fn resnet152_style_graph_linearizes() {
        let g = resnet(2, &[2, 2, 2], 10);
        let groups = linearize(&g, &common_nodes(&g));
        assert!(groups.len() >= 6, "groups: {}", groups.len());
        // groups are contiguous in topo order
        let mut last_max = 0;
        for grp in &groups {
            let mn = *grp.iter().min().unwrap();
            let mx = *grp.iter().max().unwrap();
            assert!(mn >= last_max);
            last_max = mx;
        }
    }

    #[test]
    fn inplace_children_extend_groups() {
        let mut b = GraphBuilder::new("ip");
        let x = b.input("x", vec![8, 16]);
        let w = b.param("w", vec![16, 16]);
        let h = b.matmul("h", x, w);
        let r = b.ew_unary_inplace("relu", EwUnary::Relu, h);
        let w2 = b.param("w2", vec![16, 16]);
        let y = b.matmul("y", r, w2);
        b.output(&[y]);
        let g = b.finish().unwrap();
        let groups = linearize(&g, &common_nodes(&g));
        let gid = |id: NodeId| {
            groups.iter().position(|grp| grp.contains(&id)).unwrap()
        };
        // h cannot end a group because its child relu is in-place
        assert_eq!(gid(h), gid(r));
    }

    #[test]
    fn all_common_graph_yields_no_groups() {
        let mut b = GraphBuilder::new("c");
        let ids = b.input_ids("ids", vec![4]);
        b.output(&[ids]);
        let g = b.finish().unwrap();
        let groups = linearize(&g, &common_nodes(&g));
        assert!(groups.is_empty());
    }
}
