//! Device mesh (§2.1, §4.2): a logical N-D tensor over physical devices,
//! built so that every axis group has uniform communication capability,
//! plus the α-β cost model for each collective on each axis.

use super::detector::ClusterInfo;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
    Broadcast,
}

#[derive(Debug, Clone)]
pub struct DeviceMesh {
    /// Logical shape, e.g. [2, 4]; product == number of devices.
    pub shape: Vec<usize>,
    /// Physical device ids in row-major logical order.
    pub devices: Vec<usize>,
    /// Per-axis worst-pair latency (alpha, seconds).
    pub axis_alpha: Vec<f64>,
    /// Per-axis weakest-link bandwidth (1/beta, bytes/second).
    pub axis_beta: Vec<f64>,
}

impl DeviceMesh {
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn n_axes(&self) -> usize {
        self.shape.len()
    }

    pub fn axis_size(&self, axis: usize) -> usize {
        self.shape[axis]
    }

    /// Single-device degenerate mesh.
    pub fn trivial() -> DeviceMesh {
        DeviceMesh {
            shape: vec![1],
            devices: vec![0],
            axis_alpha: vec![0.0],
            axis_beta: vec![f64::INFINITY],
        }
    }

    /// Device groups that vary along `axis` with other coords fixed.
    pub fn axis_groups(&self, axis: usize) -> Vec<Vec<usize>> {
        let n = self.devices.len();
        let mut strides = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.shape[i + 1];
        }
        let mut groups = Vec::new();
        let mut seen = vec![false; n];
        for start in 0..n {
            if seen[start] {
                continue;
            }
            let mut group = Vec::with_capacity(self.shape[axis]);
            for k in 0..self.shape[axis] {
                let idx = start + k * strides[axis];
                // only valid if start's coord along axis is 0
                if (start / strides[axis]) % self.shape[axis] != 0 {
                    break;
                }
                group.push(idx);
            }
            if group.len() == self.shape[axis] {
                for &g in &group {
                    seen[g] = true;
                }
                groups.push(group.iter().map(|&i| self.devices[i]).collect());
            }
        }
        groups
    }

    /// α-β time of a collective moving `bytes` (the full logical tensor
    /// participating on this axis) across axis `axis`.
    ///
    /// Standard ring formulas:
    ///   all-reduce:      2(n−1)/n · S/B + 2(n−1)α
    ///   all-gather:       (n−1)/n · S/B +  (n−1)α   (S = gathered size)
    ///   reduce-scatter:   (n−1)/n · S/B +  (n−1)α
    ///   all-to-all:       (n−1)/n · S/B +  (n−1)α   (balanced permute)
    ///   broadcast:              S/B     +  (n−1)α   (pipelined)
    pub fn collective_time(&self, op: Collective, bytes: f64, axis: usize)
                           -> f64 {
        let n = self.shape[axis] as f64;
        if n <= 1.0 {
            return 0.0;
        }
        let b = self.axis_beta[axis];
        let a = self.axis_alpha[axis];
        match op {
            Collective::AllReduce => {
                2.0 * (n - 1.0) / n * bytes / b + 2.0 * (n - 1.0) * a
            }
            Collective::AllGather
            | Collective::ReduceScatter
            | Collective::AllToAll => {
                (n - 1.0) / n * bytes / b + (n - 1.0) * a
            }
            Collective::Broadcast => bytes / b + (n - 1.0) * a,
        }
    }

    /// Build a mesh of `shape` over the detected cluster, assigning devices
    /// hierarchically so the *innermost* (last) axis gets the
    /// best-connected groups — the assignment rule of §4.2.
    pub fn build(info: &ClusterInfo, shape: &[usize]) -> Option<DeviceMesh> {
        let n: usize = shape.iter().product();
        if n != info.n {
            return None;
        }
        // start from singleton groups; merge along axes innermost-first
        let mut groups: Vec<Vec<usize>> =
            (0..info.n).map(|d| vec![d]).collect();
        for &axis_size in shape.iter().rev() {
            if axis_size == 1 {
                continue;
            }
            if groups.len() % axis_size != 0 {
                return None;
            }
            groups = merge_groups(info, groups, axis_size);
        }
        assert_eq!(groups.len(), 1);
        let devices = groups.pop().unwrap();

        let mut mesh = DeviceMesh {
            shape: shape.to_vec(),
            devices,
            axis_alpha: vec![0.0; shape.len()],
            axis_beta: vec![f64::INFINITY; shape.len()],
        };
        for axis in 0..shape.len() {
            let mut worst_a: f64 = 0.0;
            let mut worst_b = f64::INFINITY;
            for group in mesh.axis_groups(axis) {
                if group.len() < 2 {
                    continue;
                }
                worst_a = worst_a.max(info.group_alpha(&group));
                worst_b = worst_b.min(info.bus_bandwidth(&group));
            }
            mesh.axis_alpha[axis] = worst_a;
            mesh.axis_beta[axis] = worst_b;
        }
        Some(mesh)
    }

    /// All candidate mesh shapes for n devices (up to 3 axes), e.g. for 8:
    /// [8], [2,4], [4,2], [2,2,2] — the planner tries each.
    pub fn candidate_shapes(n: usize) -> Vec<Vec<usize>> {
        let mut out = vec![vec![n]];
        for a in 2..n {
            if n % a == 0 {
                out.push(vec![a, n / a]);
                let rest = n / a;
                for b in 2..rest {
                    if rest % b == 0 {
                        out.push(vec![a, b, rest / b]);
                    }
                }
            }
        }
        if n == 1 {
            return vec![vec![1]];
        }
        out
    }
}

/// Merge consecutive groups into super-groups of `k` groups, greedily
/// maximizing the weakest inter-group bandwidth inside each super-group.
fn merge_groups(info: &ClusterInfo, mut groups: Vec<Vec<usize>>, k: usize)
                -> Vec<Vec<usize>> {
    let group_bw = |a: &[usize], b: &[usize]| -> f64 {
        let mut min_bw = f64::INFINITY;
        for &x in a {
            for &y in b {
                min_bw = min_bw.min(info.beta[x][y]);
            }
        }
        min_bw
    };
    let mut out = Vec::new();
    while !groups.is_empty() {
        let mut cur = groups.remove(0);
        for _ in 1..k {
            // pick the remaining group with the best weakest-link bandwidth
            let (best_i, _) = groups
                .iter()
                .enumerate()
                .map(|(i, g)| (i, group_bw(&cur, g)))
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .expect("divisibility checked by caller");
            let g = groups.remove(best_i);
            cur.extend(g);
        }
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::detector::detect;
    use crate::cluster::topology::{SimCluster, GB};

    fn fig5_info() -> ClusterInfo {
        detect(&SimCluster::partially_connected_8gpu(), 42)
    }

    #[test]
    fn mesh_2x4_keeps_numa_nodes_on_inner_axis() {
        let info = fig5_info();
        let mesh = DeviceMesh::build(&info, &[2, 4]).unwrap();
        // inner axis (axis 1) groups must be the NUMA quads -> PCIe bw
        let inner = mesh.axis_groups(1);
        for g in &inner {
            let mut s = g.clone();
            s.sort_unstable();
            assert!(
                s == vec![0, 1, 2, 3] || s == vec![4, 5, 6, 7],
                "inner group crossed NUMA: {s:?}"
            );
        }
        assert!(mesh.axis_beta[1] > 15.0 * GB); // PCIe, not cross-NUMA
        assert!(mesh.axis_beta[0] < 15.0 * GB); // outer axis crosses NUMA
    }

    #[test]
    fn mesh_4x2_puts_nvlink_pairs_inner() {
        let info = fig5_info();
        let mesh = DeviceMesh::build(&info, &[4, 2]).unwrap();
        for g in mesh.axis_groups(1) {
            let mut s = g.clone();
            s.sort_unstable();
            assert_eq!(s[0] / 2, s[1] / 2, "inner pair not NVLink: {s:?}");
        }
        assert!(mesh.axis_beta[1] > 100.0 * GB);
    }

    #[test]
    fn axis_groups_partition_devices() {
        let info = fig5_info();
        let mesh = DeviceMesh::build(&info, &[2, 2, 2]).unwrap();
        for axis in 0..3 {
            let groups = mesh.axis_groups(axis);
            assert_eq!(groups.len(), 4);
            let mut all: Vec<usize> = groups.concat();
            all.sort_unstable();
            assert_eq!(all, (0..8).collect::<Vec<_>>());
        }
    }

    #[test]
    fn collective_costs_scale_correctly() {
        let mesh = DeviceMesh {
            shape: vec![4],
            devices: vec![0, 1, 2, 3],
            axis_alpha: vec![1e-6],
            axis_beta: vec![100.0 * GB],
        };
        let s = 1e9; // 1 GB
        let ar = mesh.collective_time(Collective::AllReduce, s, 0);
        let ag = mesh.collective_time(Collective::AllGather, s, 0);
        // all-reduce moves 2x the data of all-gather
        assert!((ar / ag - 2.0).abs() < 0.01);
        // 1 GB over 100 GB/s, factor 1.5 => 15 ms
        assert!((ar - 0.015).abs() / 0.015 < 0.01);
    }

    #[test]
    fn single_axis_of_one_is_free() {
        let mesh = DeviceMesh::trivial();
        assert_eq!(
            mesh.collective_time(Collective::AllReduce, 1e9, 0),
            0.0
        );
    }

    #[test]
    fn candidate_shapes_enumerate_factorizations() {
        let shapes = DeviceMesh::candidate_shapes(8);
        assert!(shapes.contains(&vec![8]));
        assert!(shapes.contains(&vec![2, 4]));
        assert!(shapes.contains(&vec![4, 2]));
        assert!(shapes.contains(&vec![2, 2, 2]));
        assert_eq!(DeviceMesh::candidate_shapes(1), vec![vec![1]]);
        // 7 is prime: only [7]
        assert_eq!(DeviceMesh::candidate_shapes(7), vec![vec![7]]);
    }

    #[test]
    fn mesh_build_rejects_wrong_size() {
        let info = fig5_info();
        assert!(DeviceMesh::build(&info, &[3, 3]).is_none());
    }
}
