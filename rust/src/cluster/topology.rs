//! Simulated cluster interconnect (substitution for the paper's 8×A100
//! testbed — see DESIGN.md §Substitutions).
//!
//! A `SimCluster` is a per-pair (latency, bandwidth) matrix plus a
//! `measure()` API shaped exactly like a p2p microbenchmark, so the
//! detector consumes it the same way it would consume real NCCL probes.

use crate::util::rng::Rng;

pub const GB: f64 = 1e9;

#[derive(Debug, Clone)]
pub struct SimCluster {
    pub name: String,
    pub n: usize,
    /// latency\[i\]\[j\] seconds for a zero-byte message.
    pub latency: Vec<Vec<f64>>,
    /// bandwidth\[i\]\[j\] bytes/second, symmetric.
    pub bandwidth: Vec<Vec<f64>>,
    /// multiplicative measurement noise (std dev, e.g. 0.03 = 3%).
    pub noise: f64,
    /// Per-device compute scale relative to the reference device model
    /// (1.0 = reference; 0.5 = half-speed older generation). Spec-sheet
    /// data, not probed — mixed-generation nodes advertise their class.
    pub compute_scale: Vec<f64>,
}

impl SimCluster {
    fn uniform(name: &str, n: usize, lat: f64, bw: f64) -> SimCluster {
        SimCluster {
            name: name.to_string(),
            n,
            latency: vec![vec![lat; n]; n],
            bandwidth: vec![vec![bw; n]; n],
            noise: 0.03,
            compute_scale: vec![1.0; n],
        }
    }

    /// The paper's Fig. 5 topology: 8 GPUs, NVLink only between the 4
    /// adjacent pairs (0,1)(2,3)(4,5)(6,7); PCIe inside a NUMA node
    /// ({0..3}, {4..7}); the lowest bandwidth across NUMA domains.
    /// Bandwidth classes follow §7: NVLink >200 GB/s, PCIe ~20 GB/s,
    /// cross-NUMA ~10 GB/s.
    pub fn partially_connected_8gpu() -> SimCluster {
        let mut c = SimCluster::uniform("fig5-8xA100", 8, 12e-6, 10.0 * GB);
        for i in 0..8 {
            for j in 0..8 {
                if i == j {
                    continue;
                }
                if i / 2 == j / 2 {
                    // NVLink pair
                    c.latency[i][j] = 2e-6;
                    c.bandwidth[i][j] = 200.0 * GB;
                } else if i / 4 == j / 4 {
                    // same NUMA node via PCIe
                    c.latency[i][j] = 6e-6;
                    c.bandwidth[i][j] = 20.0 * GB;
                }
                // else: cross-NUMA defaults (12 µs, 10 GB/s)
            }
        }
        c
    }

    /// Fully NVLink-connected single node (DGX-like).
    pub fn fully_connected(n: usize) -> SimCluster {
        SimCluster::uniform(&format!("nvlink-{n}"), n, 2e-6, 200.0 * GB)
    }

    /// Multi-node cluster: `nodes` × `per_node` devices; NVLink inside a
    /// node, `net_gbps` Ethernet/IB across nodes.
    pub fn multi_node(nodes: usize, per_node: usize, net_gbps: f64)
                      -> SimCluster {
        let n = nodes * per_node;
        let mut c = SimCluster::uniform(
            &format!("{nodes}x{per_node}"),
            n,
            25e-6,
            net_gbps / 8.0 * GB,
        );
        for i in 0..n {
            for j in 0..n {
                if i != j && i / per_node == j / per_node {
                    c.latency[i][j] = 2e-6;
                    c.bandwidth[i][j] = 200.0 * GB;
                }
            }
        }
        c
    }

    /// Single device (experiment alpha).
    pub fn single() -> SimCluster {
        SimCluster::uniform("single", 1, 0.0, f64::INFINITY)
    }

    /// The first `n` devices of the Fig-5 box — the paper's sub-cluster
    /// configurations for experiments alpha (1), beta (2), gamma (4),
    /// delta (8).
    pub fn fig5_prefix(n: usize) -> SimCluster {
        assert!(
            (1..=8).contains(&n),
            "fig5 has 8 devices, asked for {n}"
        );
        if n == 1 {
            return SimCluster::single();
        }
        let mut c = SimCluster::partially_connected_8gpu();
        c.name = format!("fig5-prefix-{n}");
        c.n = n;
        c.latency.truncate(n);
        c.bandwidth.truncate(n);
        for row in c.latency.iter_mut() {
            row.truncate(n);
        }
        for row in c.bandwidth.iter_mut() {
            row.truncate(n);
        }
        c.compute_scale.truncate(n);
        c
    }

    /// Remove one device from a cluster (elastic shrink: a node was lost
    /// or preempted). The surviving devices keep their relative links and
    /// renumber contiguously.
    pub fn without_device(&self, lost: usize) -> SimCluster {
        assert!(lost < self.n, "device {lost} not in cluster");
        assert!(self.n > 1, "cannot shrink a single-device cluster");
        let keep: Vec<usize> =
            (0..self.n).filter(|&d| d != lost).collect();
        let pick = |m: &Vec<Vec<f64>>| -> Vec<Vec<f64>> {
            keep.iter()
                .map(|&i| keep.iter().map(|&j| m[i][j]).collect())
                .collect()
        };
        SimCluster {
            name: format!("{}-drop{lost}", self.name),
            n: keep.len(),
            latency: pick(&self.latency),
            bandwidth: pick(&self.bandwidth),
            noise: self.noise,
            compute_scale: keep
                .iter()
                .map(|&i| self.compute_scale[i])
                .collect(),
        }
    }

    /// The Fig-5 box after losing device `lost` — the canonical elastic
    /// shrink scenario for `automap replan`.
    pub fn fig5_drop(lost: usize) -> SimCluster {
        SimCluster::partially_connected_8gpu().without_device(lost)
    }

    /// Fig-5 with the second NUMA node degraded to half compute (e.g.
    /// thermal throttling or power capping): links unchanged, devices
    /// 4..8 run at 0.5× the reference FLOPs.
    pub fn fig5_degraded() -> SimCluster {
        let mut c = SimCluster::partially_connected_8gpu();
        c.name = "fig5-degraded".into();
        for s in c.compute_scale.iter_mut().skip(4) {
            *s = 0.5;
        }
        c
    }

    /// Mixed-generation Fig-5: the first NUMA node is current-gen, the
    /// second is a previous-gen part (0.6× FLOPs, half the NVLink and
    /// PCIe bandwidth inside the node). Cross-NUMA links unchanged.
    pub fn fig5_mixed() -> SimCluster {
        let mut c = SimCluster::partially_connected_8gpu();
        c.name = "fig5-mixed".into();
        for i in 4..8 {
            c.compute_scale[i] = 0.6;
            for j in 4..8 {
                if i != j {
                    c.bandwidth[i][j] /= 2.0;
                }
            }
        }
        c
    }

    /// Fig-5 grown by one extra NVLink pair hanging off the second NUMA
    /// node (elastic grow: 10 devices, the new pair reaches everyone
    /// else at cross-NUMA speed).
    pub fn fig5_grow() -> SimCluster {
        let base = SimCluster::partially_connected_8gpu();
        let n = 10;
        let mut c = SimCluster::uniform("fig5-grow10", n, 12e-6, 10.0 * GB);
        for i in 0..8 {
            for j in 0..8 {
                c.latency[i][j] = base.latency[i][j];
                c.bandwidth[i][j] = base.bandwidth[i][j];
            }
        }
        for i in 8..10 {
            for j in 8..10 {
                if i != j {
                    // the new pair is NVLink-connected internally
                    c.latency[i][j] = 2e-6;
                    c.bandwidth[i][j] = 200.0 * GB;
                }
            }
        }
        c
    }

    /// Simulated p2p transfer time for `bytes` between `src` and `dst`,
    /// with multiplicative noise — what a real ping-pong benchmark returns.
    pub fn measure(&self, src: usize, dst: usize, bytes: usize,
                   rng: &mut Rng) -> f64 {
        assert!(src < self.n && dst < self.n);
        if src == dst {
            return 0.0;
        }
        let ideal =
            self.latency[src][dst] + bytes as f64 / self.bandwidth[src][dst];
        let jitter = 1.0 + self.noise * rng.normal();
        ideal * jitter.max(0.5)
    }

    /// Ideal (noise-free) p2p time — used by cost models after detection.
    pub fn ideal_time(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        if src == dst {
            0.0
        } else {
            self.latency[src][dst] + bytes as f64 / self.bandwidth[src][dst]
        }
    }

    /// Slowest-link bandwidth within a device group (the paper's point:
    /// the weakest link gates collective performance on an axis).
    pub fn bottleneck_bandwidth(&self, group: &[usize]) -> f64 {
        let mut min_bw = f64::INFINITY;
        for (ai, &a) in group.iter().enumerate() {
            for &b in &group[ai + 1..] {
                min_bw = min_bw.min(self.bandwidth[a][b]);
            }
        }
        min_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_has_three_bandwidth_classes() {
        let c = SimCluster::partially_connected_8gpu();
        assert_eq!(c.bandwidth[0][1], 200.0 * GB); // NVLink pair
        assert_eq!(c.bandwidth[0][2], 20.0 * GB); // PCIe same NUMA
        assert_eq!(c.bandwidth[0][4], 10.0 * GB); // cross NUMA
        assert_eq!(c.bandwidth[6][7], 200.0 * GB);
    }

    #[test]
    fn measurement_noise_is_bounded() {
        let c = SimCluster::partially_connected_8gpu();
        let mut rng = Rng::new(0);
        let ideal = c.ideal_time(0, 1, 1 << 26);
        for _ in 0..100 {
            let m = c.measure(0, 1, 1 << 26, &mut rng);
            assert!((m / ideal - 1.0).abs() < 0.25);
        }
    }

    #[test]
    fn bottleneck_detects_weakest_link() {
        let c = SimCluster::partially_connected_8gpu();
        assert_eq!(c.bottleneck_bandwidth(&[0, 1]), 200.0 * GB);
        assert_eq!(c.bottleneck_bandwidth(&[0, 1, 2, 3]), 20.0 * GB);
        assert_eq!(c.bottleneck_bandwidth(&[0, 4]), 10.0 * GB);
        assert_eq!(
            c.bottleneck_bandwidth(&(0..8).collect::<Vec<_>>()),
            10.0 * GB
        );
    }

    #[test]
    fn fig5_prefix_matches_full_box() {
        let full = SimCluster::partially_connected_8gpu();
        let c4 = SimCluster::fig5_prefix(4);
        assert_eq!(c4.n, 4);
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(c4.bandwidth[i][j], full.bandwidth[i][j]);
                assert_eq!(c4.latency[i][j], full.latency[i][j]);
            }
        }
        assert_eq!(SimCluster::fig5_prefix(1).n, 1);
        assert_eq!(SimCluster::fig5_prefix(8).n, 8);
    }

    #[test]
    fn multi_node_wires_internal_nvlink() {
        let c = SimCluster::multi_node(2, 4, 100.0);
        assert_eq!(c.bandwidth[0][3], 200.0 * GB);
        assert_eq!(c.bandwidth[0][4], 12.5 * GB);
    }

    #[test]
    fn drop_device_renumbers_and_keeps_links() {
        let full = SimCluster::partially_connected_8gpu();
        let c = SimCluster::fig5_drop(3);
        assert_eq!(c.n, 7);
        // old device 4 is new device 3; (4,5) NVLink pair survives
        assert_eq!(c.bandwidth[3][4], full.bandwidth[4][5]);
        assert_eq!(c.bandwidth[0][1], 200.0 * GB);
        assert_eq!(c.compute_scale.len(), 7);
    }

    #[test]
    fn scenario_clusters_are_consistent() {
        let d = SimCluster::fig5_degraded();
        assert_eq!(d.compute_scale[0], 1.0);
        assert_eq!(d.compute_scale[7], 0.5);
        assert_eq!(d.bandwidth[4][5], 200.0 * GB, "links unchanged");
        let m = SimCluster::fig5_mixed();
        assert_eq!(m.compute_scale[5], 0.6);
        assert_eq!(m.bandwidth[4][5], 100.0 * GB, "older NVLink halved");
        assert_eq!(m.bandwidth[0][4], 10.0 * GB, "cross-NUMA unchanged");
        let g = SimCluster::fig5_grow();
        assert_eq!(g.n, 10);
        assert_eq!(g.bandwidth[8][9], 200.0 * GB);
        assert_eq!(g.bandwidth[0][8], 10.0 * GB);
        assert_eq!(g.compute_scale, vec![1.0; 10]);
    }
}
