//! Cluster detector (§4.2): probes p2p latency/bandwidth with small/large
//! messages, recovers the fine-grained topology (bandwidth tiers), and
//! derives all-reduce bus bandwidth via B = S/t · 2(n−1)/n.

use crate::util::rng::Rng;

use super::topology::SimCluster;

const SMALL_MSG: usize = 1 << 10; // 1 KiB -> latency dominated
const LARGE_MSG: usize = 1 << 26; // 64 MiB -> bandwidth dominated
const PROBE_REPS: usize = 5;

#[derive(Debug, Clone)]
pub struct ClusterInfo {
    pub n: usize,
    /// Estimated per-pair latency (alpha) in seconds.
    pub alpha: Vec<Vec<f64>>,
    /// Estimated per-pair bandwidth (1/beta) in bytes/second.
    pub beta: Vec<Vec<f64>>,
    /// Distinct bandwidth tiers, descending (e.g. [NVLink, PCIe, x-NUMA]).
    pub tiers: Vec<f64>,
    /// tier_of\[i\]\[j\] = index into `tiers` for the (i, j) link.
    pub tier_of: Vec<Vec<usize>>,
    /// Per-device compute scale relative to the reference device model
    /// (all 1.0 for a homogeneous cluster). Read from the cluster's
    /// spec sheet, not probed, so it carries no measurement noise.
    pub flops_scale: Vec<f64>,
}

impl ClusterInfo {
    /// Groups of devices mutually connected at tier `t` *or better*
    /// (connected components of the >= tier-t subgraph).
    pub fn groups_at_tier(&self, t: usize) -> Vec<Vec<usize>> {
        let mut seen = vec![false; self.n];
        let mut out = Vec::new();
        for s in 0..self.n {
            if seen[s] {
                continue;
            }
            let mut comp = vec![s];
            seen[s] = true;
            let mut stack = vec![s];
            while let Some(u) = stack.pop() {
                for v in 0..self.n {
                    if !seen[v] && u != v && self.tier_of[u][v] <= t {
                        seen[v] = true;
                        comp.push(v);
                        stack.push(v);
                    }
                }
            }
            comp.sort_unstable();
            out.push(comp);
        }
        out
    }

    /// Estimated ring-all-reduce *bus bandwidth* for a device group
    /// (gated by the weakest link, per the paper's observation).
    pub fn bus_bandwidth(&self, group: &[usize]) -> f64 {
        if group.len() < 2 {
            return f64::INFINITY;
        }
        let mut min_bw = f64::INFINITY;
        for (ai, &a) in group.iter().enumerate() {
            for &b in &group[ai + 1..] {
                min_bw = min_bw.min(self.beta[a][b]);
            }
        }
        min_bw
    }

    pub fn group_alpha(&self, group: &[usize]) -> f64 {
        let mut worst: f64 = 0.0;
        for (ai, &a) in group.iter().enumerate() {
            for &b in &group[ai + 1..] {
                worst = worst.max(self.alpha[a][b]);
            }
        }
        worst
    }

    /// Restrict the detected topology to a device subset (renumbered
    /// `0..devs.len()` in the given order). The pipeline partitioner
    /// hands each candidate stage a sliced view of the cluster so the
    /// per-stage intra-op solve sees exactly the submesh it would own;
    /// the global `tiers` list is kept as-is (tier indices stay
    /// comparable across slices of one probe).
    pub fn slice(&self, devs: &[usize]) -> ClusterInfo {
        let pick = |m: &Vec<Vec<f64>>| -> Vec<Vec<f64>> {
            devs.iter()
                .map(|&i| devs.iter().map(|&j| m[i][j]).collect())
                .collect()
        };
        ClusterInfo {
            n: devs.len(),
            alpha: pick(&self.alpha),
            beta: pick(&self.beta),
            tiers: self.tiers.clone(),
            tier_of: devs
                .iter()
                .map(|&i| {
                    devs.iter().map(|&j| self.tier_of[i][j]).collect()
                })
                .collect(),
            flops_scale: devs
                .iter()
                .map(|&i| self.flops_scale[i])
                .collect(),
        }
    }

    /// The slowest device class in the cluster (SPMD stages run in
    /// lockstep, so the weakest device gates the whole slice).
    pub fn min_flops_scale(&self) -> f64 {
        self.flops_scale.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// True when every device is the reference class.
    pub fn is_uniform_compute(&self) -> bool {
        self.flops_scale.iter().all(|&s| s == 1.0)
    }
}

/// Probe every pair with small (latency) and large (bandwidth) messages —
/// the same microbenchmark schedule a real detector runs over NCCL.
pub fn detect(cluster: &SimCluster, seed: u64) -> ClusterInfo {
    let n = cluster.n;
    let mut rng = Rng::new(seed);
    let mut alpha = vec![vec![0.0; n]; n];
    let mut beta = vec![vec![f64::INFINITY; n]; n];

    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            // latency: median of small-message round trips
            let mut lat: Vec<f64> = (0..PROBE_REPS)
                .map(|_| cluster.measure(i, j, SMALL_MSG, &mut rng))
                .collect();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            alpha[i][j] = lat[PROBE_REPS / 2];
            // bandwidth: large message, subtract measured latency
            let mut bw: Vec<f64> = (0..PROBE_REPS)
                .map(|_| {
                    let t = cluster.measure(i, j, LARGE_MSG, &mut rng);
                    LARGE_MSG as f64 / (t - alpha[i][j]).max(1e-9)
                })
                .collect();
            bw.sort_by(|a, b| a.partial_cmp(b).unwrap());
            beta[i][j] = bw[PROBE_REPS / 2];
        }
    }

    // tier classification: cluster the measured bandwidths; two links are
    // in the same tier if within 30% of each other (noise ≪ the >2x gaps
    // between real interconnect classes)
    let mut all_bw: Vec<f64> = (0..n)
        .flat_map(|i| (0..n).filter(move |&j| j != i).map({
            let beta = &beta;
            move |j| beta[i][j]
        }))
        .collect();
    all_bw.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut tiers: Vec<f64> = Vec::new();
    for &bw in &all_bw {
        match tiers.last() {
            Some(&t) if bw > t * 0.7 => {
                // same tier: keep running representative (max)
            }
            _ => tiers.push(bw),
        }
    }
    let tier_of: Vec<Vec<usize>> = (0..n)
        .map(|i| {
            (0..n)
                .map(|j| {
                    if i == j {
                        0
                    } else {
                        tiers
                            .iter()
                            .position(|&t| beta[i][j] > t * 0.7)
                            .unwrap_or(tiers.len() - 1)
                    }
                })
                .collect()
        })
        .collect();

    ClusterInfo {
        n,
        alpha,
        beta,
        tiers,
        tier_of,
        // spec-sheet read, deliberately noise-free: device classes are
        // advertised, not measured, so replan fingerprints stay stable
        flops_scale: cluster.compute_scale.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::topology::GB;

    #[test]
    fn detects_fig5_three_tiers() {
        let c = SimCluster::partially_connected_8gpu();
        let info = detect(&c, 42);
        assert_eq!(info.tiers.len(), 3, "tiers: {:?}", info.tiers);
        // NVLink pairs land in tier 0
        assert_eq!(info.tier_of[0][1], 0);
        assert_eq!(info.tier_of[2][3], 0);
        // PCIe same-NUMA in tier 1
        assert_eq!(info.tier_of[0][2], 1);
        // cross-NUMA in tier 2
        assert_eq!(info.tier_of[0][4], 2);
    }

    #[test]
    fn recovers_nvlink_pairs_as_tier0_groups() {
        let c = SimCluster::partially_connected_8gpu();
        let info = detect(&c, 7);
        let pairs = info.groups_at_tier(0);
        assert_eq!(
            pairs,
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]]
        );
        let numa = info.groups_at_tier(1);
        assert_eq!(numa, vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]);
        let all = info.groups_at_tier(2);
        assert_eq!(all.len(), 1);
    }

    #[test]
    fn bandwidth_estimates_are_close() {
        let c = SimCluster::partially_connected_8gpu();
        let info = detect(&c, 3);
        assert!((info.beta[0][1] / (200.0 * GB) - 1.0).abs() < 0.15);
        assert!((info.beta[0][4] / (10.0 * GB) - 1.0).abs() < 0.15);
    }

    #[test]
    fn uniform_cluster_is_single_tier() {
        let c = SimCluster::fully_connected(4);
        let info = detect(&c, 5);
        assert_eq!(info.tiers.len(), 1);
        assert_eq!(info.groups_at_tier(0).len(), 1);
    }

    #[test]
    fn slice_restricts_and_renumbers() {
        let c = SimCluster::partially_connected_8gpu();
        let info = detect(&c, 42);
        let quad = info.slice(&[4, 5, 6, 7]);
        assert_eq!(quad.n, 4);
        // (4,5) is an NVLink pair in the full box -> (0,1) in the slice
        assert_eq!(quad.beta[0][1], info.beta[4][5]);
        assert_eq!(quad.alpha[2][3], info.alpha[6][7]);
        assert_eq!(quad.tier_of[0][2], info.tier_of[4][6]);
        // tiers stay global so tier indices remain comparable
        assert_eq!(quad.tiers, info.tiers);
        let one = info.slice(&[3]);
        assert_eq!(one.n, 1);
        assert_eq!(one.beta.len(), 1);
    }

    #[test]
    fn flops_scale_is_noise_free_and_slices() {
        let c = SimCluster::fig5_degraded();
        let info = detect(&c, 42);
        assert_eq!(info.flops_scale, c.compute_scale);
        assert!(!info.is_uniform_compute());
        assert_eq!(info.min_flops_scale(), 0.5);
        let fast = info.slice(&[0, 1, 2, 3]);
        assert!(fast.is_uniform_compute());
        let slow = info.slice(&[4, 5]);
        assert_eq!(slow.flops_scale, vec![0.5, 0.5]);
        assert_eq!(slow.min_flops_scale(), 0.5);
    }

    #[test]
    fn bus_bandwidth_is_weakest_link() {
        let c = SimCluster::partially_connected_8gpu();
        let info = detect(&c, 9);
        let bw_pair = info.bus_bandwidth(&[0, 1]);
        let bw_numa = info.bus_bandwidth(&[0, 1, 2, 3]);
        assert!(bw_pair > 5.0 * bw_numa);
    }
}
