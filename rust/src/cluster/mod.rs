//! Cluster detection + device mesh (§4.2): simulated interconnects, the
//! probing detector, bandwidth-aware mesh construction, and the α-β
//! collective cost model.

pub mod detector;
pub mod mesh;
pub mod topology;

pub use detector::{detect, ClusterInfo};
pub use mesh::{Collective, DeviceMesh};
pub use topology::{SimCluster, GB};
