//! Microbatched 1F1B pipeline replay — the inter-op extension of the
//! discrete-event executor (`sim::exec`).
//!
//! The intra-op replayer models one SPMD mesh: every device runs the same
//! program and collectives rendezvous along mesh axes. Pipeline
//! parallelism breaks that symmetry — each *stage* owns a submesh and a
//! slice of the model, and stages talk through point-to-point transfers,
//! not collectives. This module models each stage as one logical queue
//! (SPMD *within* a stage means one queue per stage suffices), emits the
//! standard non-interleaved 1F1B schedule per stage — warmup forwards,
//! steady one-forward-one-backward with Megatron-style *combined*
//! `send_forward_recv_backward` rendezvous, cooldown backwards — and runs
//! it through the same [`run_programs`] engine, so P2P deadlocks and
//! mismatched boundary transfers are detected exactly like collective
//! bugs are in the intra-op replay.
//!
//! The combined steady-state ops are not an optimization nicety: with
//! strict in-order rendezvous, separate send-forward and recv-backward
//! ops on one boundary interleave differently on the two sides and
//! deadlock. Pairing them (as Megatron's schedule does) makes both sides
//! post the boundary's ops in one agreed total order — which this module
//! relies on and the oracle tests exercise for many (stages,
//! microbatches) shapes.
//!
//! Memory is a per-microbatch ledger: a forward retains `act/B` (the
//! stage's full-batch retained set split over `B` microbatches), the
//! matching backward frees it, and 1F1B's in-flight bound
//! `min(S - s, B)` emerges from the schedule rather than being assumed.
//! Per-stage parameters are allocated up front by a zero-time op, so one
//! trace "device" ledger per stage starts at that stage's own resident
//! model data.

use anyhow::{bail, ensure, Result};

use crate::ckpt::{build_stages, common_nodes, linearize, Block};
use crate::cluster::DeviceMesh;
use crate::gen::{CommReason, ExecutionPlan, P2pTransfer};
use crate::graph::op::Op;
use crate::graph::Graph;
use crate::sim::DeviceModel;

use super::exec::{coll_sig, exposed_grad, run_programs, times_from_plan,
                  validate_exec, SimOp};
use super::trace::{EventKind, SimTrace};

/// Aggregate phase costs of one compiled pipeline stage, derived from its
/// lowered intra-op plan with exactly the planner's accounting (so the
/// per-stage numbers the 1F1B replay consumes are the ones the intra-op
/// oracle already validates).
#[derive(Debug, Clone, Default)]
pub struct StagePhases {
    /// Full-batch forward sweep: stage compute + correctness comm +
    /// resharding collectives (run once, on the forward, per the shared
    /// modeling contract).
    pub fwd: f64,
    /// Full-batch backward sweep: backward compute + correctness comm +
    /// checkpoint recomputation.
    pub bwd: f64,
    /// Gradient-sync time left exposed after overlap, once per step.
    pub exposed_grad: f64,
    /// Bytes retained between a microbatch's forward and backward at
    /// full batch: kept saved-sets plus checkpointed entry boundaries.
    pub act_bytes: f64,
    /// Worst transient high during a forward (o_f, ckpt internals), B.
    pub fwd_transient: f64,
    /// Worst transient high during a backward (o_b, recompute retention,
    /// the boundary gradient δ), bytes.
    pub bwd_transient: f64,
    /// Parameter + resident-input memory of the stage, bytes.
    pub param_bytes: f64,
}

/// Derive [`StagePhases`] from a lowered plan. The decomposition sums to
/// the single-device replay's step time (`fwd + bwd + exposed_grad` ==
/// `replay_exec(..).step_time`); a unit test pins that identity.
pub fn stage_phases(
    g: &Graph,
    mesh: &DeviceMesh,
    ep: &ExecutionPlan,
    dev: &DeviceModel,
) -> Result<StagePhases> {
    validate_exec(g.len(), mesh, ep)?;
    let groups = linearize(g, &common_nodes(g));
    let times = times_from_plan(g, ep, mesh);
    let stages = build_stages(g, &groups, dev, Some(&times));
    let ln = stages.len();
    let blocks: Vec<Block> = match &ep.ckpt {
        Some(r) => {
            ensure!(
                r.partitions(ln),
                "invalid checkpoint schedule: blocks do not partition \
                 the {ln}-stage linearization of '{}'",
                g.name
            );
            r.blocks.clone()
        }
        None if ln == 0 => Vec::new(),
        None => vec![Block { start: 0, end: ln - 1, checkpointed: false }],
    };

    let wa_in =
        |s: usize| if s == 0 { 0.0 } else { stages[s - 1].wa_out };
    let wd = stages.last().map(|s| s.wa_out).unwrap_or(0.0);

    let mut p = StagePhases::default();
    for st in &stages {
        p.fwd += st.uf + st.uf_comm;
        p.bwd += st.ub + st.ub_comm;
    }
    for c in &ep.comms {
        if c.reason == CommReason::Resharding {
            p.fwd += c.time; // resharding runs once, on the forward sweep
        }
    }
    for blk in &blocks {
        if blk.checkpointed {
            // the block re-runs its forward once during backward and
            // briefly re-retains its saved sets while doing so
            let mut re_retained = 0.0;
            for s in blk.start..=blk.end {
                p.bwd += stages[s].uf + stages[s].uf_comm;
                re_retained += stages[s].wbar;
            }
            p.bwd_transient = p.bwd_transient.max(re_retained);
            p.act_bytes += wa_in(blk.start);
            for s in blk.start..=blk.end {
                let internal = wa_in(s) + stages[s].wa_out + stages[s].of;
                p.fwd_transient = p.fwd_transient.max(internal);
            }
        } else {
            for s in blk.start..=blk.end {
                p.act_bytes += stages[s].wbar;
                p.fwd_transient = p.fwd_transient.max(stages[s].of);
            }
        }
        for s in blk.start..=blk.end {
            p.bwd_transient = p.bwd_transient.max(stages[s].ob);
        }
    }
    // the boundary gradient δ lives only through a microbatch's backward
    p.bwd_transient += wd;

    let grad_total: f64 =
        ep.decisions.values().map(|d| d.grad_comm).sum();
    let bwd_compute: f64 = ep
        .decisions
        .values()
        .map(|d| crate::ckpt::bwd_share(d.compute_time))
        .sum();
    p.exposed_grad = exposed_grad(grad_total, bwd_compute);

    p.param_bytes = ep
        .decisions
        .iter()
        .filter(|(id, _)| matches!(g.node(**id).op, Op::Placeholder(_)))
        .map(|(_, d)| d.mem_bytes)
        .sum();
    Ok(p)
}

/// Everything the 1F1B replayer needs to know about one pipeline stage —
/// artifact-shaped so a saved `PipelineSolution` replays without the
/// model graph.
#[derive(Debug, Clone)]
pub struct PipelineStageSpec {
    pub phases: StagePhases,
    /// Incoming boundary transfer from the previous stage (`None` only
    /// for stage 0).
    pub p2p_in: Option<P2pTransfer>,
}

// -- 1F1B program emission --------------------------------------------------

fn compute_op(
    kind: EventKind,
    label: String,
    secs: f64,
    alloc: f64,
    transient: f64,
    free: f64,
) -> SimOp {
    SimOp::Compute { kind, label, secs, alloc, transient, free }
}

/// A boundary rendezvous between stage `b` and `b+1`. Both sides MUST
/// construct their op through this one function so labels, durations and
/// signatures agree bit-for-bit.
fn boundary_op(
    b: usize,
    label: String,
    secs: f64,
) -> SimOp {
    let group = vec![b, b + 1];
    let sig = coll_sig(&label, secs, &group);
    SimOp::Collective {
        kind: EventKind::Comm,
        label,
        secs,
        group,
        sig,
    }
}

/// Replay a stage chain under the non-interleaved 1F1B schedule with
/// `microbatches` microbatches. Returns a [`SimTrace`] whose "devices"
/// are the stage queues (`devices[s].peak_mem` is stage `s`'s per-device
/// peak); `step_time` is the pipeline-latency of one training step.
pub fn replay_1f1b(
    stages: &[PipelineStageSpec],
    microbatches: usize,
) -> Result<SimTrace> {
    let ns = stages.len();
    ensure!(ns > 0, "cannot replay an empty pipeline");
    ensure!(microbatches > 0, "need at least one microbatch");
    let nb = microbatches;
    let bf = nb as f64;
    for (s, st) in stages.iter().enumerate() {
        for x in [st.phases.fwd, st.phases.bwd, st.phases.exposed_grad,
                  st.phases.act_bytes, st.phases.fwd_transient,
                  st.phases.bwd_transient, st.phases.param_bytes]
        {
            ensure!(
                x.is_finite() && x >= 0.0,
                "stage {s}: non-finite or negative phase cost"
            );
        }
        if s == 0 {
            ensure!(
                st.p2p_in.is_none(),
                "stage 0 cannot have an incoming boundary"
            );
        } else {
            ensure!(
                st.p2p_in.is_some(),
                "stage {s} is missing its incoming boundary transfer"
            );
        }
    }

    // boundary b sits between stage b and b+1; its link data lives on
    // the downstream stage's spec
    let link = |b: usize| stages[b + 1].p2p_in.as_ref().unwrap();
    let fwd_op = |b: usize, mb: usize| {
        boundary_op(
            b,
            format!("p2p fwd mb{mb} b{b}"),
            link(b).fwd_time(nb),
        )
    };
    let bwd_op = |b: usize, mb: usize| {
        boundary_op(
            b,
            format!("p2p bwd mb{mb} b{b}"),
            link(b).bwd_time(nb),
        )
    };
    let fb_op = |b: usize, f_mb: usize, b_mb: usize| {
        boundary_op(
            b,
            format!("p2p fwd mb{f_mb} bwd mb{b_mb} b{b}"),
            link(b).fb_time(nb),
        )
    };

    let mut progs: Vec<Vec<SimOp>> = Vec::with_capacity(ns);
    for (s, st) in stages.iter().enumerate() {
        let p = &st.phases;
        let (f_mb, b_mb) = (p.fwd / bf, p.bwd / bf);
        let act_mb = p.act_bytes / bf;
        let warm = (ns - 1 - s).min(nb);
        let steady = nb - warm;
        let mut prog = Vec::new();
        if p.param_bytes > 0.0 {
            prog.push(compute_op(
                EventKind::FwdCompute,
                format!("params s{s}"),
                0.0,
                p.param_bytes,
                0.0,
                0.0,
            ));
        }
        let fwd = |i: usize| {
            compute_op(
                EventKind::FwdCompute,
                format!("F mb{i} s{s}"),
                f_mb,
                act_mb,
                p.fwd_transient / bf,
                0.0,
            )
        };
        let bwd = |i: usize| {
            compute_op(
                EventKind::BwdCompute,
                format!("B mb{i} s{s}"),
                b_mb,
                0.0,
                p.bwd_transient / bf,
                act_mb,
            )
        };
        // warmup: fill the pipe
        for i in 0..warm {
            if s > 0 {
                prog.push(fwd_op(s - 1, i));
            }
            prog.push(fwd(i));
            if s + 1 < ns {
                prog.push(fwd_op(s, i));
            }
        }
        // first steady input arrives before the 1F1B loop starts
        if steady > 0 && s > 0 {
            prog.push(fwd_op(s - 1, warm));
        }
        // steady state: one forward, one backward, combined rendezvous
        for k in 0..steady {
            let (i_f, i_b) = (warm + k, k);
            prog.push(fwd(i_f));
            if s + 1 < ns {
                prog.push(fb_op(s, i_f, i_b));
            }
            prog.push(bwd(i_b));
            if s > 0 {
                if k + 1 < steady {
                    prog.push(fb_op(s - 1, i_f + 1, i_b));
                } else {
                    prog.push(bwd_op(s - 1, i_b));
                }
            }
        }
        // cooldown: drain the pipe
        for i in steady..nb {
            if s + 1 < ns {
                prog.push(bwd_op(s, i));
            }
            prog.push(bwd(i));
            if s > 0 {
                prog.push(bwd_op(s - 1, i));
            }
        }
        if p.exposed_grad > 0.0 {
            prog.push(compute_op(
                EventKind::GradSync,
                format!("grad-sync s{s} (exposed)"),
                p.exposed_grad,
                0.0,
                0.0,
                0.0,
            ));
        }
        progs.push(prog);
    }

    let trace = run_programs(&progs, &[ns], 0.0).map_err(|e| {
        anyhow::anyhow!("1F1B replay ({ns} stages, {nb} microbatches): {e}")
    })?;
    if trace.step_time < 0.0 {
        bail!("1F1B replay produced a negative step time");
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::P2pTransfer;

    fn spec(fwd: f64, bwd: f64, act: f64, pm: f64,
            p2p: Option<P2pTransfer>) -> PipelineStageSpec {
        PipelineStageSpec {
            phases: StagePhases {
                fwd,
                bwd,
                exposed_grad: 0.0,
                act_bytes: act,
                fwd_transient: 0.0,
                bwd_transient: 0.0,
                param_bytes: pm,
            },
            p2p_in: p2p,
        }
    }

    fn free_link(from: usize) -> P2pTransfer {
        P2pTransfer {
            from_stage: from,
            to_stage: from + 1,
            bytes_fwd: 0.0,
            bytes_bwd: 0.0,
            alpha: 0.0,
            beta: f64::INFINITY,
            streams: 1,
        }
    }

    #[test]
    fn stage_phases_decompose_the_intra_op_replay() {
        use crate::graph::models::{gpt2, Gpt2Cfg};
        use crate::layout::LayoutManager;
        use crate::solver::{solve, SolveOpts, SolverGraph};
        let g = gpt2(&Gpt2Cfg::mini());
        let mesh = DeviceMesh {
            shape: vec![2],
            devices: vec![0, 1],
            axis_alpha: vec![1e-6],
            axis_beta: vec![1e11],
        };
        let dev = DeviceModel::a100_80gb();
        let lm = LayoutManager::new(mesh.clone());
        let sg = SolverGraph::build(&g, &mesh, &dev, &lm);
        let sol = solve(
            &sg,
            1e13,
            SolveOpts { anneal_iters: 150, ..Default::default() },
        )
        .unwrap();
        let ep = crate::gen::lower(&g, &sg, &sol, &mesh, &lm, None);
        let ph = stage_phases(&g, &mesh, &ep, &dev).unwrap();
        let replay =
            crate::sim::exec::replay_exec(&g, &mesh, &ep, &dev).unwrap();
        // fwd + bwd + exposed grad IS the serialized intra-op replay —
        // the phase split only re-associates the same op durations
        let total = ph.fwd + ph.bwd + ph.exposed_grad;
        let rel = (total - replay.step_time).abs() / replay.step_time;
        assert!(
            rel < 1e-9,
            "phases {total} vs replay {}",
            replay.step_time
        );
        assert!(ph.param_bytes > 0.0 && ph.act_bytes > 0.0);
        assert!(ph.fwd > 0.0 && ph.bwd > 0.0);
    }

    #[test]
    fn single_stage_is_exactly_the_serial_step() {
        for nb in [1usize, 3, 8] {
            let t = replay_1f1b(&[spec(1.0, 2.0, 100.0, 10.0, None)], nb)
                .unwrap();
            // (fwd + bwd) split over B microbatches sums back exactly
            assert!(
                (t.step_time - 3.0).abs() < 1e-9,
                "B={nb}: {}",
                t.step_time
            );
            // one microbatch in flight: params + act/B
            assert!(
                (t.devices[0].peak_mem - (10.0 + 100.0 / nb as f64))
                    .abs()
                    < 1e-6
            );
        }
    }

    #[test]
    fn balanced_two_stage_pipeline_has_the_textbook_makespan() {
        // equal stages, free links: makespan = (B + S - 1) * (f+b)/B
        let stages = vec![
            spec(1.0, 1.0, 80.0, 5.0, None),
            spec(1.0, 1.0, 80.0, 5.0, Some(free_link(0))),
        ];
        let nb = 4;
        let t = replay_1f1b(&stages, nb).unwrap();
        let per_mb = 2.0 / nb as f64;
        let expect = (nb + 2 - 1) as f64 * per_mb;
        assert!(
            (t.step_time - expect).abs() < 1e-9,
            "got {}, want {expect}",
            t.step_time
        );
        // stage 0 holds min(S - 0, B) = 2 microbatches in flight,
        // stage 1 holds 1
        let act_mb = 80.0 / nb as f64;
        assert!(
            (t.devices[0].peak_mem - (5.0 + 2.0 * act_mb)).abs() < 1e-6,
            "stage0 peak {}",
            t.devices[0].peak_mem
        );
        assert!(
            (t.devices[1].peak_mem - (5.0 + act_mb)).abs() < 1e-6,
            "stage1 peak {}",
            t.devices[1].peak_mem
        );
    }

    #[test]
    fn deep_pipelines_never_deadlock() {
        for ns in 1..=5usize {
            for nb in 1..=6usize {
                let mut stages = vec![spec(0.6, 1.1, 10.0, 1.0, None)];
                for s in 1..ns {
                    stages.push(spec(
                        0.5 + s as f64 * 0.1,
                        1.0,
                        10.0,
                        1.0,
                        Some(free_link(s - 1)),
                    ));
                }
                let t = replay_1f1b(&stages, nb).unwrap_or_else(|e| {
                    panic!("S={ns} B={nb}: {e}")
                });
                assert!(t.step_time > 0.0);
                // every stage ends with all activations freed: final
                // resident memory equals its params
                for (s, d) in t.devices.iter().enumerate() {
                    let last = d.events.last().unwrap();
                    assert!(
                        (last.mem - 1.0).abs() < 1e-6,
                        "S={ns} B={nb} stage {s}: leaked {}",
                        last.mem
                    );
                }
            }
        }
    }

    #[test]
    fn in_flight_memory_is_bounded_by_min_depth_microbatches() {
        let ns = 4;
        for nb in [2usize, 3, 8] {
            let mut stages = vec![spec(1.0, 1.0, 100.0, 0.0, None)];
            for s in 1..ns {
                stages.push(spec(1.0, 1.0, 100.0, 0.0,
                                 Some(free_link(s - 1))));
            }
            let t = replay_1f1b(&stages, nb).unwrap();
            for (s, d) in t.devices.iter().enumerate() {
                let bound =
                    (ns - s).min(nb) as f64 * 100.0 / nb as f64;
                assert!(
                    d.peak_mem <= bound + 1e-6,
                    "B={nb} stage {s}: peak {} > bound {bound}",
                    d.peak_mem
                );
            }
        }
    }

    #[test]
    fn p2p_latency_slows_the_pipeline() {
        let mk = |alpha: f64| {
            vec![
                spec(1.0, 1.0, 0.0, 0.0, None),
                spec(
                    1.0,
                    1.0,
                    0.0,
                    0.0,
                    Some(P2pTransfer {
                        from_stage: 0,
                        to_stage: 1,
                        bytes_fwd: 1e6,
                        bytes_bwd: 1e6,
                        alpha,
                        beta: 1e9,
                        streams: 1,
                    }),
                ),
            ]
        };
        let fast = replay_1f1b(&mk(0.0), 4).unwrap();
        let slow = replay_1f1b(&mk(0.05), 4).unwrap();
        assert!(
            slow.step_time > fast.step_time,
            "latency must surface: {} vs {}",
            slow.step_time,
            fast.step_time
        );
    }

    #[test]
    fn rejects_malformed_stage_lists() {
        assert!(replay_1f1b(&[], 2).is_err());
        assert!(
            replay_1f1b(&[spec(1.0, 1.0, 0.0, 0.0, None)], 0).is_err()
        );
        // stage 1 without a boundary link
        let bad = vec![
            spec(1.0, 1.0, 0.0, 0.0, None),
            spec(1.0, 1.0, 0.0, 0.0, None),
        ];
        assert!(replay_1f1b(&bad, 2).is_err());
        // stage 0 with a spurious incoming link
        let bad =
            vec![spec(1.0, 1.0, 0.0, 0.0, Some(free_link(0)))];
        assert!(replay_1f1b(&bad, 2).is_err());
    }
}
