//! Microbatched pipeline-schedule replay — the inter-op extension of the
//! discrete-event executor (`sim::exec`).
//!
//! The intra-op replayer models one SPMD mesh: every device runs the same
//! program and collectives rendezvous along mesh axes. Pipeline
//! parallelism breaks that symmetry — each *stage* owns a submesh and a
//! slice of the model, and stages talk through point-to-point transfers,
//! not collectives. This module models each stage as one logical queue
//! (SPMD *within* a stage means one queue per stage suffices), emits a
//! per-stage program for the chosen [`Schedule`], and runs it through the
//! same [`run_programs`] engine, so P2P deadlocks and mismatched boundary
//! transfers are detected exactly like collective bugs are in the
//! intra-op replay. Two schedules are in the zoo:
//!
//! * **Non-interleaved 1F1B** ([`replay_1f1b`]): warmup forwards, steady
//!   one-forward-one-backward with Megatron-style *combined*
//!   `send_forward_recv_backward` rendezvous, cooldown backwards. The
//!   combined steady-state ops are not an optimization nicety: with
//!   strict in-order rendezvous, separate send-forward and recv-backward
//!   ops on one boundary interleave differently on the two sides and
//!   deadlock. Pairing them (as Megatron's schedule does) makes both
//!   sides post the boundary's ops in one agreed total order.
//!
//! * **Interleaved (virtual-stage) 1F1B** ([`replay_interleaved`]): each
//!   physical stage holds `v` model chunks, microbatches advance in
//!   stage-count-sized groups, and the warmup/cooldown bubble shrinks
//!   ~`v`× at the price of `v`× boundary P2P traffic plus a wraparound
//!   link from the last stage back to the first. Emission here is a
//!   *weave*: a dependency-respecting global walk over all stages' step
//!   lists that appends every boundary rendezvous to BOTH endpoint
//!   programs at a single global moment. Each per-stage program is then
//!   a restriction of one global op sequence, so the two sides of any
//!   boundary post its ops in one agreed order and no ordering cycle
//!   across boundaries can form — deadlock-freedom by construction,
//!   with the engine's detector still checking. A peephole pass
//!   (`merge_duplex`) then fuses adjacent opposite-direction
//!   rendezvous into single full-duplex ops, generalizing the 1F1B
//!   combined ops to the interleaved (and wraparound) boundaries.
//!
//! Memory is a per-microbatch ledger: a forward retains `act/B` (split
//! further over `v` chunks when interleaved), the matching backward
//! frees it, and the in-flight bound — `min(S - s, B)` for 1F1B, the
//! deeper `min(v·S − s, B)`-shaped ramp for interleaved (see
//! [`Schedule::in_flight_bound`]) — emerges from the schedule rather
//! than being assumed. Per-stage parameters are allocated up front by a
//! zero-time op, so one trace "device" ledger per stage starts at that
//! stage's own resident model data.

use anyhow::{anyhow, bail, ensure, Result};

use crate::ckpt::{build_stages, common_nodes, linearize, Block};
use crate::cluster::DeviceMesh;
use crate::gen::{CommReason, ExecutionPlan, P2pTransfer};
use crate::graph::op::Op;
use crate::graph::Graph;
use crate::sim::DeviceModel;

use super::exec::{coll_sig, exposed_grad, run_programs, times_from_plan,
                  validate_exec, SimOp};
use super::trace::{EventKind, SimTrace};

/// Aggregate phase costs of one compiled pipeline stage, derived from its
/// lowered intra-op plan with exactly the planner's accounting (so the
/// per-stage numbers the 1F1B replay consumes are the ones the intra-op
/// oracle already validates).
#[derive(Debug, Clone, Default)]
pub struct StagePhases {
    /// Full-batch forward sweep: stage compute + correctness comm +
    /// resharding collectives (run once, on the forward, per the shared
    /// modeling contract).
    pub fwd: f64,
    /// Full-batch backward sweep: backward compute + correctness comm +
    /// checkpoint recomputation.
    pub bwd: f64,
    /// Gradient-sync time left exposed after overlap, once per step.
    pub exposed_grad: f64,
    /// Bytes retained between a microbatch's forward and backward at
    /// full batch: kept saved-sets plus checkpointed entry boundaries.
    pub act_bytes: f64,
    /// Worst transient high during a forward (o_f, ckpt internals), B.
    pub fwd_transient: f64,
    /// Worst transient high during a backward (o_b, recompute retention,
    /// the boundary gradient δ), bytes.
    pub bwd_transient: f64,
    /// Parameter + resident-input memory of the stage, bytes.
    pub param_bytes: f64,
}

/// Derive [`StagePhases`] from a lowered plan. The decomposition sums to
/// the single-device replay's step time (`fwd + bwd + exposed_grad` ==
/// `replay_exec(..).step_time`); a unit test pins that identity.
pub fn stage_phases(
    g: &Graph,
    mesh: &DeviceMesh,
    ep: &ExecutionPlan,
    dev: &DeviceModel,
) -> Result<StagePhases> {
    validate_exec(g.len(), mesh, ep)?;
    let groups = linearize(g, &common_nodes(g));
    let times = times_from_plan(g, ep, mesh);
    let stages = build_stages(g, &groups, dev, Some(&times));
    let ln = stages.len();
    let blocks: Vec<Block> = match &ep.ckpt {
        Some(r) => {
            ensure!(
                r.partitions(ln),
                "invalid checkpoint schedule: blocks do not partition \
                 the {ln}-stage linearization of '{}'",
                g.name
            );
            r.blocks.clone()
        }
        None if ln == 0 => Vec::new(),
        None => vec![Block { start: 0, end: ln - 1, checkpointed: false }],
    };

    let wa_in =
        |s: usize| if s == 0 { 0.0 } else { stages[s - 1].wa_out };
    let wd = stages.last().map(|s| s.wa_out).unwrap_or(0.0);

    let mut p = StagePhases::default();
    for st in &stages {
        p.fwd += st.uf + st.uf_comm;
        p.bwd += st.ub + st.ub_comm;
    }
    for c in &ep.comms {
        if c.reason == CommReason::Resharding {
            p.fwd += c.time; // resharding runs once, on the forward sweep
        }
    }
    for blk in &blocks {
        if blk.checkpointed {
            // the block re-runs its forward once during backward and
            // briefly re-retains its saved sets while doing so
            let mut re_retained = 0.0;
            for s in blk.start..=blk.end {
                p.bwd += stages[s].uf + stages[s].uf_comm;
                re_retained += stages[s].wbar;
            }
            p.bwd_transient = p.bwd_transient.max(re_retained);
            p.act_bytes += wa_in(blk.start);
            for s in blk.start..=blk.end {
                let internal = wa_in(s) + stages[s].wa_out + stages[s].of;
                p.fwd_transient = p.fwd_transient.max(internal);
            }
        } else {
            for s in blk.start..=blk.end {
                p.act_bytes += stages[s].wbar;
                p.fwd_transient = p.fwd_transient.max(stages[s].of);
            }
        }
        for s in blk.start..=blk.end {
            p.bwd_transient = p.bwd_transient.max(stages[s].ob);
        }
    }
    // the boundary gradient δ lives only through a microbatch's backward
    p.bwd_transient += wd;

    let grad_total: f64 =
        ep.decisions.values().map(|d| d.grad_comm).sum();
    let bwd_compute: f64 = ep
        .decisions
        .values()
        .map(|d| crate::ckpt::bwd_share(d.compute_time))
        .sum();
    p.exposed_grad = exposed_grad(grad_total, bwd_compute);

    p.param_bytes = ep
        .decisions
        .iter()
        .filter(|(id, _)| matches!(g.node(**id).op, Op::Placeholder(_)))
        .map(|(_, d)| d.mem_bytes)
        .sum();
    Ok(p)
}

/// Everything the 1F1B replayer needs to know about one pipeline stage —
/// artifact-shaped so a saved `PipelineSolution` replays without the
/// model graph.
#[derive(Debug, Clone)]
pub struct PipelineStageSpec {
    pub phases: StagePhases,
    /// Incoming boundary transfer from the previous stage (`None` only
    /// for stage 0).
    pub p2p_in: Option<P2pTransfer>,
}

// -- the schedule zoo -------------------------------------------------------

/// Which pipeline schedule a stage chain replays under — the
/// partitioner's schedule axis, recorded in the `PipelineSolution`
/// artifact (absent = `OneF1B`, so pre-schedule artifacts stay
/// readable).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash,
         Default)]
pub enum Schedule {
    /// Classic non-interleaved 1F1B (PipeDream-flush).
    #[default]
    OneF1B,
    /// Megatron's interleaved virtual-stage 1F1B with `v >= 2` model
    /// chunks per physical stage.
    Interleaved {
        v: usize,
    },
}

impl Schedule {
    /// Virtual chunks per physical stage (1 for non-interleaved).
    pub fn v(&self) -> usize {
        match self {
            Schedule::OneF1B => 1,
            Schedule::Interleaved { v } => *v,
        }
    }

    /// Canonical CLI/wire spelling: `1f1b` or `interleaved:<v>`.
    pub fn name(&self) -> String {
        match self {
            Schedule::OneF1B => "1f1b".to_string(),
            Schedule::Interleaved { v } => format!("interleaved:{v}"),
        }
    }

    /// Parse a canonical spelling (`1f1b`, or `interleaved:<v>` with
    /// `v >= 2`).
    pub fn parse(text: &str) -> Result<Schedule> {
        let t = text.trim();
        if t == "1f1b" {
            return Ok(Schedule::OneF1B);
        }
        if let Some(rest) = t.strip_prefix("interleaved:") {
            let v: usize = rest.parse().map_err(|_| {
                anyhow!("bad virtual-chunk count in schedule '{text}'")
            })?;
            ensure!(
                v >= 2,
                "interleaved schedule needs v >= 2 chunks, got {v}"
            );
            return Ok(Schedule::Interleaved { v });
        }
        bail!("unknown schedule '{text}' (want '1f1b' or 'interleaved:<v>')")
    }

    /// Whether this schedule can drive `ns` stages with `nb`
    /// microbatches. Interleaving advances microbatches in
    /// stage-count-sized groups (as Megatron does), so it needs
    /// `nb % ns == 0` — and at least two physical stages, since
    /// chunking a single stage buys nothing.
    pub fn feasible_for(&self, ns: usize, nb: usize) -> bool {
        match self {
            Schedule::OneF1B => ns > 0 && nb > 0,
            Schedule::Interleaved { v } => {
                *v >= 2 && ns >= 2 && nb > 0 && nb % ns == 0
            }
        }
    }

    /// Upper bound on stage `s`'s concurrently retained microbatch
    /// activations, in whole microbatches. 1F1B fills `min(S - s, B)`;
    /// the interleaved warmup runs `2(S−1−s) + (v−1)S` chunk forwards
    /// deep (one more in flight during the first steady pair), capped
    /// at the `v·B` chunk total and rounded up to microbatches —
    /// the `min(v·S − s, B)`-shaped ramp the ledger tests pin.
    pub fn in_flight_bound(
        &self,
        ns: usize,
        s: usize,
        nb: usize,
    ) -> usize {
        match self {
            Schedule::OneF1B => (ns - s).min(nb),
            Schedule::Interleaved { v } => {
                let chunks =
                    (2 * (ns - 1 - s) + (v - 1) * ns + 1).min(v * nb);
                chunks.div_ceil(*v)
            }
        }
    }
}

/// Replay a stage chain under `schedule` — the one dispatch point the
/// artifact replay, the verify oracle and the partitioner all share.
pub fn replay_schedule(
    stages: &[PipelineStageSpec],
    microbatches: usize,
    schedule: Schedule,
) -> Result<SimTrace> {
    match schedule {
        Schedule::OneF1B => replay_1f1b(stages, microbatches),
        Schedule::Interleaved { v } => {
            replay_interleaved(stages, microbatches, v)
        }
    }
}

// -- 1F1B program emission --------------------------------------------------

fn compute_op(
    kind: EventKind,
    label: String,
    secs: f64,
    alloc: f64,
    transient: f64,
    free: f64,
) -> SimOp {
    SimOp::Compute { kind, label, secs, alloc, transient, free }
}

/// A P2P rendezvous between stages `a` and `b` — any pair, since the
/// interleaved schedule's wraparound link joins the last stage back to
/// the first. Both sides MUST construct their op through this one
/// function so labels, durations and signatures agree bit-for-bit.
fn pair_op(a: usize, b: usize, label: String, secs: f64) -> SimOp {
    let mut group = vec![a, b];
    group.sort_unstable();
    let sig = coll_sig(&label, secs, &group);
    SimOp::Collective {
        kind: EventKind::Comm,
        label,
        secs,
        group,
        sig,
    }
}

/// A boundary rendezvous between stage `b` and `b+1`.
fn boundary_op(b: usize, label: String, secs: f64) -> SimOp {
    pair_op(b, b + 1, label, secs)
}

/// Shared stage-list validation for every schedule's replayer.
fn validate_stages(stages: &[PipelineStageSpec]) -> Result<()> {
    for (s, st) in stages.iter().enumerate() {
        for x in [st.phases.fwd, st.phases.bwd, st.phases.exposed_grad,
                  st.phases.act_bytes, st.phases.fwd_transient,
                  st.phases.bwd_transient, st.phases.param_bytes]
        {
            ensure!(
                x.is_finite() && x >= 0.0,
                "stage {s}: non-finite or negative phase cost"
            );
        }
        if s == 0 {
            ensure!(
                st.p2p_in.is_none(),
                "stage 0 cannot have an incoming boundary"
            );
        } else {
            ensure!(
                st.p2p_in.is_some(),
                "stage {s} is missing its incoming boundary transfer"
            );
        }
    }
    Ok(())
}

/// Negative step times out of a replay are a bug — but a long tick
/// accumulation over near-zero-cost ops can drift a sub-epsilon hair
/// below zero in floats. Tolerate exactly that: clamp tiny negatives to
/// zero, keep the bail for genuinely negative times.
fn clamp_step_time(mut trace: SimTrace, what: &str) -> Result<SimTrace> {
    if trace.step_time < 0.0 {
        let tol = 1e-9
            * (1.0 + trace.compute_time.abs() + trace.comm_time.abs());
        ensure!(
            trace.step_time >= -tol,
            "{what} replay produced a negative step time ({})",
            trace.step_time
        );
        trace.step_time = 0.0;
    }
    Ok(trace)
}

/// Replay a stage chain under the non-interleaved 1F1B schedule with
/// `microbatches` microbatches. Returns a [`SimTrace`] whose "devices"
/// are the stage queues (`devices[s].peak_mem` is stage `s`'s per-device
/// peak); `step_time` is the pipeline-latency of one training step.
pub fn replay_1f1b(
    stages: &[PipelineStageSpec],
    microbatches: usize,
) -> Result<SimTrace> {
    let ns = stages.len();
    ensure!(ns > 0, "cannot replay an empty pipeline");
    ensure!(microbatches > 0, "need at least one microbatch");
    let nb = microbatches;
    let bf = nb as f64;
    validate_stages(stages)?;

    // boundary b sits between stage b and b+1; its link data lives on
    // the downstream stage's spec
    let link = |b: usize| stages[b + 1].p2p_in.as_ref().unwrap();
    let fwd_op = |b: usize, mb: usize| {
        boundary_op(
            b,
            format!("p2p fwd mb{mb} b{b}"),
            link(b).fwd_time(nb),
        )
    };
    let bwd_op = |b: usize, mb: usize| {
        boundary_op(
            b,
            format!("p2p bwd mb{mb} b{b}"),
            link(b).bwd_time(nb),
        )
    };
    let fb_op = |b: usize, f_mb: usize, b_mb: usize| {
        boundary_op(
            b,
            format!("p2p fwd mb{f_mb} bwd mb{b_mb} b{b}"),
            link(b).fb_time(nb),
        )
    };

    let mut progs: Vec<Vec<SimOp>> = Vec::with_capacity(ns);
    for (s, st) in stages.iter().enumerate() {
        let p = &st.phases;
        let (f_mb, b_mb) = (p.fwd / bf, p.bwd / bf);
        let act_mb = p.act_bytes / bf;
        let warm = (ns - 1 - s).min(nb);
        let steady = nb - warm;
        let mut prog = Vec::new();
        if p.param_bytes > 0.0 {
            prog.push(compute_op(
                EventKind::FwdCompute,
                format!("params s{s}"),
                0.0,
                p.param_bytes,
                0.0,
                0.0,
            ));
        }
        let fwd = |i: usize| {
            compute_op(
                EventKind::FwdCompute,
                format!("F mb{i} s{s}"),
                f_mb,
                act_mb,
                p.fwd_transient / bf,
                0.0,
            )
        };
        let bwd = |i: usize| {
            compute_op(
                EventKind::BwdCompute,
                format!("B mb{i} s{s}"),
                b_mb,
                0.0,
                p.bwd_transient / bf,
                act_mb,
            )
        };
        // warmup: fill the pipe
        for i in 0..warm {
            if s > 0 {
                prog.push(fwd_op(s - 1, i));
            }
            prog.push(fwd(i));
            if s + 1 < ns {
                prog.push(fwd_op(s, i));
            }
        }
        // first steady input arrives before the 1F1B loop starts
        if steady > 0 && s > 0 {
            prog.push(fwd_op(s - 1, warm));
        }
        // steady state: one forward, one backward, combined rendezvous
        for k in 0..steady {
            let (i_f, i_b) = (warm + k, k);
            prog.push(fwd(i_f));
            if s + 1 < ns {
                prog.push(fb_op(s, i_f, i_b));
            }
            prog.push(bwd(i_b));
            if s > 0 {
                if k + 1 < steady {
                    prog.push(fb_op(s - 1, i_f + 1, i_b));
                } else {
                    prog.push(bwd_op(s - 1, i_b));
                }
            }
        }
        // cooldown: drain the pipe
        for i in steady..nb {
            if s + 1 < ns {
                prog.push(bwd_op(s, i));
            }
            prog.push(bwd(i));
            if s > 0 {
                prog.push(bwd_op(s - 1, i));
            }
        }
        if p.exposed_grad > 0.0 {
            prog.push(compute_op(
                EventKind::GradSync,
                format!("grad-sync s{s} (exposed)"),
                p.exposed_grad,
                0.0,
                0.0,
                0.0,
            ));
        }
        progs.push(prog);
    }

    let trace = run_programs(&progs, &[ns], 0.0).map_err(|e| {
        anyhow!("1F1B replay ({ns} stages, {nb} microbatches): {e}")
    })?;
    clamp_step_time(trace, "1F1B")
}

// -- interleaved (virtual-stage) 1F1B emission ------------------------------

/// One schedule slot of a stage's interleaved step list.
#[derive(Clone, Copy)]
enum Step {
    /// Forward of (chunk, microbatch).
    F(usize, usize),
    /// Backward of (chunk, microbatch).
    B(usize, usize),
}

/// Replay a stage chain under Megatron's interleaved (virtual-stage)
/// 1F1B schedule: each physical stage models `v` equal sub-chunks of its
/// span, so model chunk `c` of stage `s` is virtual stage `u = c·S + s`.
/// Microbatches advance in stage-count-sized groups (hence the
/// `B % S == 0` requirement), the warmup ramp runs `2(S−1−s) + (v−1)S`
/// chunk forwards deep, and every virtual boundary is a real rendezvous:
/// each physical cut is crossed `v` times per microbatch and the chunk
/// handoff from the last stage back to the first becomes a wraparound
/// link. That interior cut was never profiled, so its per-crossing times
/// are approximated by the mean of the recorded physical cuts.
///
/// Emission is a dependency-respecting *weave* over all stages' step
/// lists (see the module docs): every rendezvous lands in both endpoint
/// programs at one global moment, which is what makes the emitted
/// programs deadlock-free under the engine's strict in-order rendezvous.
pub fn replay_interleaved(
    stages: &[PipelineStageSpec],
    microbatches: usize,
    v: usize,
) -> Result<SimTrace> {
    let ns = stages.len();
    ensure!(ns > 0, "cannot replay an empty pipeline");
    ensure!(microbatches > 0, "need at least one microbatch");
    ensure!(v >= 2, "interleaved 1F1B needs v >= 2 chunks, got {v}");
    ensure!(
        microbatches % ns == 0,
        "interleaved 1F1B needs microbatches divisible by stages \
         (B={microbatches}, S={ns})"
    );
    validate_stages(stages)?;

    let nb = microbatches;
    let bf = nb as f64;
    let vf = v as f64;
    let nv = ns * v; // virtual stages == model chunks
    let total = nb * v; // chunk slots per stage per direction

    let link = |b: usize| stages[b + 1].p2p_in.as_ref().unwrap();
    let (wrap_f, wrap_b) = if ns > 1 {
        let mut f = 0.0;
        let mut b = 0.0;
        for x in 0..ns - 1 {
            f += link(x).fwd_time(nb);
            b += link(x).bwd_time(nb);
        }
        let m = (ns - 1) as f64;
        (f / m, b / m)
    } else {
        (0.0, 0.0)
    };
    // edge `u` joins virtual stage u to u+1: (producer stage, consumer
    // stage, fwd crossing secs, bwd crossing secs), or None when both
    // chunks share one queue (S == 1)
    let edge = |u: usize| -> Option<(usize, usize, f64, f64)> {
        let a = u % ns;
        let b = (u + 1) % ns;
        if a == b {
            None
        } else if b == a + 1 {
            Some((a, b, link(a).fwd_time(nb), link(a).bwd_time(nb)))
        } else {
            Some((a, b, wrap_f, wrap_b))
        }
    };

    // Megatron's traversal: microbatches advance in groups of S; within
    // a group a stage runs all S on one chunk before switching (forwards
    // ascend chunks, backwards descend).
    let grp = ns * v;
    let fwd_ci = |k: usize| (k % grp / ns, k / grp * ns + k % ns);
    let bwd_ci =
        |k: usize| (v - 1 - k % grp / ns, k / grp * ns + k % ns);
    let mut steps: Vec<Vec<Step>> = Vec::with_capacity(ns);
    for s in 0..ns {
        let w = (2 * (ns - 1 - s) + (v - 1) * ns).min(total);
        let steady = total - w;
        let mut list = Vec::with_capacity(2 * total);
        for k in 0..w {
            let (c, i) = fwd_ci(k);
            list.push(Step::F(c, i));
        }
        for k in 0..steady {
            let (c, i) = fwd_ci(w + k);
            list.push(Step::F(c, i));
            let (c, i) = bwd_ci(k);
            list.push(Step::B(c, i));
        }
        for k in steady..total {
            let (c, i) = bwd_ci(k);
            list.push(Step::B(c, i));
        }
        steps.push(list);
    }

    // -- the weave --------------------------------------------------------
    // Execute each stage's fixed step list in order, earliest
    // virtual-clock first among data-ready stages; every rendezvous is
    // appended to BOTH endpoint programs at that single global moment.
    let mut progs: Vec<Vec<SimOp>> = stages
        .iter()
        .enumerate()
        .map(|(s, st)| {
            let mut p = Vec::new();
            if st.phases.param_bytes > 0.0 {
                p.push(compute_op(
                    EventKind::FwdCompute,
                    format!("params s{s}"),
                    0.0,
                    st.phases.param_bytes,
                    0.0,
                    0.0,
                ));
            }
            p
        })
        .collect();
    // physical direction of every emitted rendezvous, for merge_duplex
    let mut dirs: std::collections::HashMap<String, (usize, usize)> =
        std::collections::HashMap::new();
    let mut idx = vec![0usize; ns];
    let mut clock = vec![0.0f64; ns];
    let mut done_f = vec![vec![false; nb]; nv];
    let mut done_b = vec![vec![false; nb]; nv];
    let mut tf = vec![vec![0.0f64; nb]; nv];
    let mut tb = vec![vec![0.0f64; nb]; nv];
    let mut left: usize = steps.iter().map(|l| l.len()).sum();
    while left > 0 {
        // recv-carrying steps win clock ties so producers service their
        // sends before running ahead of a waiting consumer
        let mut pick: Option<(f64, bool, usize)> = None;
        for s in 0..ns {
            let Some(&st) = steps[s].get(idx[s]) else { continue };
            let (ready, recv) = match st {
                Step::F(c, i) => {
                    let u = c * ns + s;
                    if u == 0 {
                        (clock[s], false)
                    } else if !done_f[u - 1][i] {
                        continue;
                    } else {
                        (
                            clock[s].max(tf[u - 1][i]),
                            edge(u - 1).is_some(),
                        )
                    }
                }
                Step::B(c, i) => {
                    let u = c * ns + s;
                    if !done_f[u][i] {
                        continue;
                    }
                    if u == nv - 1 {
                        (clock[s], false)
                    } else if !done_b[u + 1][i] {
                        continue;
                    } else {
                        (clock[s].max(tb[u + 1][i]), edge(u).is_some())
                    }
                }
            };
            let better = match &pick {
                None => true,
                Some((r, rv, ps)) => {
                    ready < *r
                        || (ready == *r
                            && ((recv && !*rv)
                                || (recv == *rv && s < *ps)))
                }
            };
            if better {
                pick = Some((ready, recv, s));
            }
        }
        let Some((_, _, s)) = pick else {
            bail!(
                "interleaved 1F1B weave wedged: no stage is data-ready \
                 (S={ns}, B={nb}, v={v})"
            );
        };
        let p = &stages[s].phases;
        match steps[s][idx[s]] {
            Step::F(c, i) => {
                let u = c * ns + s;
                let mut arrive = clock[s];
                if u > 0 {
                    if let Some((a, b, secs, _)) = edge(u - 1) {
                        let op = pair_op(
                            a,
                            b,
                            format!("p2p fwd e{} mb{i}", u - 1),
                            secs,
                        );
                        if let SimOp::Collective { sig, .. } = &op {
                            dirs.insert(sig.clone(), (a, b));
                        }
                        progs[a].push(op.clone());
                        progs[b].push(op);
                        arrive = arrive.max(tf[u - 1][i] + secs);
                    } else {
                        arrive = arrive.max(tf[u - 1][i]);
                    }
                }
                progs[s].push(compute_op(
                    EventKind::FwdCompute,
                    format!("F c{c} mb{i} s{s}"),
                    p.fwd / bf / vf,
                    p.act_bytes / bf / vf,
                    p.fwd_transient / bf,
                    0.0,
                ));
                clock[s] = arrive + p.fwd / bf / vf;
                done_f[u][i] = true;
                tf[u][i] = clock[s];
            }
            Step::B(c, i) => {
                let u = c * ns + s;
                let mut arrive = clock[s];
                if u + 1 < nv {
                    if let Some((a, b, _, secs)) = edge(u) {
                        // the gradient flows consumer -> producer
                        let op = pair_op(
                            a,
                            b,
                            format!("p2p bwd e{u} mb{i}"),
                            secs,
                        );
                        if let SimOp::Collective { sig, .. } = &op {
                            dirs.insert(sig.clone(), (b, a));
                        }
                        progs[a].push(op.clone());
                        progs[b].push(op);
                        arrive = arrive.max(tb[u + 1][i] + secs);
                    } else {
                        arrive = arrive.max(tb[u + 1][i]);
                    }
                }
                progs[s].push(compute_op(
                    EventKind::BwdCompute,
                    format!("B c{c} mb{i} s{s}"),
                    p.bwd / bf / vf,
                    0.0,
                    p.bwd_transient / bf,
                    p.act_bytes / bf / vf,
                ));
                clock[s] = arrive + p.bwd / bf / vf;
                done_b[u][i] = true;
                tb[u][i] = clock[s];
            }
        }
        idx[s] += 1;
        left -= 1;
    }
    for (s, st) in stages.iter().enumerate() {
        if st.phases.exposed_grad > 0.0 {
            progs[s].push(compute_op(
                EventKind::GradSync,
                format!("grad-sync s{s} (exposed)"),
                st.phases.exposed_grad,
                0.0,
                0.0,
                0.0,
            ));
        }
    }
    merge_duplex(&mut progs, &dirs);

    let trace = run_programs(&progs, &[ns], 0.0).map_err(|e| {
        anyhow!(
            "interleaved 1F1B replay ({ns} stages, {nb} microbatches, \
             v={v}): {e}"
        )
    })?;
    clamp_step_time(trace, "interleaved 1F1B")
}

/// Fuse adjacent opposite-direction rendezvous on one stage pair into a
/// single full-duplex op (`secs = max`), generalizing 1F1B's combined
/// steady-state `send_forward_recv_backward` to the interleaved (and
/// wraparound) boundaries. Only pairs adjacent in BOTH endpoint programs
/// fuse, which keeps every program a restriction of the same global op
/// sequence — the deadlock-freedom argument survives the rewrite.
/// `dirs` maps each rendezvous signature to its physical (from, to):
/// on a two-stage ring the same {0, 1} group carries both directions,
/// so the label alone cannot tell full duplex from half.
fn merge_duplex(
    progs: &mut [Vec<SimOp>],
    dirs: &std::collections::HashMap<String, (usize, usize)>,
) {
    use std::collections::HashMap;
    let parts = |op: &SimOp| -> Option<(String, f64, Vec<usize>, String)> {
        match op {
            SimOp::Collective { label, secs, group, sig, .. } => Some((
                label.clone(),
                *secs,
                group.clone(),
                sig.clone(),
            )),
            _ => None,
        }
    };
    // every rendezvous sig appears in exactly two programs
    let mut at: HashMap<String, Vec<(usize, usize)>> = HashMap::new();
    for (x, prog) in progs.iter().enumerate() {
        for (j, op) in prog.iter().enumerate() {
            if let SimOp::Collective { sig, .. } = op {
                at.entry(sig.clone()).or_default().push((x, j));
            }
        }
    }
    let peer = |sig: &str, x: usize| -> Option<(usize, usize)> {
        at.get(sig)?
            .iter()
            .copied()
            .find(|&(px, _)| px != x)
    };
    // (program, position) -> replacement (first of pair) / drop (second)
    let mut repl: HashMap<(usize, usize), Option<SimOp>> = HashMap::new();
    for (x, prog) in progs.iter().enumerate() {
        for j in 0..prog.len().saturating_sub(1) {
            if repl.contains_key(&(x, j))
                || repl.contains_key(&(x, j + 1))
            {
                continue;
            }
            let (Some((l1, s1, g1, sg1)), Some((l2, s2, g2, sg2))) =
                (parts(&prog[j]), parts(&prog[j + 1]))
            else {
                continue;
            };
            if g1 != g2 {
                continue;
            }
            // full duplex only: physically opposite directions
            match (dirs.get(&sg1), dirs.get(&sg2)) {
                (Some(&(f1, t1)), Some(&(f2, t2)))
                    if f1 == t2 && t1 == f2 => {}
                _ => continue,
            }
            // and adjacent, in the same order, on the peer side
            let (Some((y1, j1)), Some((y2, j2))) =
                (peer(&sg1, x), peer(&sg2, x))
            else {
                continue;
            };
            if y1 != y2 || j2 != j1 + 1 {
                continue;
            }
            if repl.contains_key(&(y1, j1))
                || repl.contains_key(&(y1, j2))
            {
                continue;
            }
            let op =
                pair_op(g1[0], g1[1], format!("{l1} + {l2}"), s1.max(s2));
            repl.insert((x, j), Some(op.clone()));
            repl.insert((x, j + 1), None);
            repl.insert((y1, j1), Some(op));
            repl.insert((y1, j2), None);
        }
    }
    if repl.is_empty() {
        return;
    }
    for (x, prog) in progs.iter_mut().enumerate() {
        let old = std::mem::take(prog);
        for (j, op) in old.into_iter().enumerate() {
            match repl.get(&(x, j)) {
                None => prog.push(op),
                Some(Some(m)) => prog.push(m.clone()),
                Some(None) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::P2pTransfer;

    fn spec(fwd: f64, bwd: f64, act: f64, pm: f64,
            p2p: Option<P2pTransfer>) -> PipelineStageSpec {
        PipelineStageSpec {
            phases: StagePhases {
                fwd,
                bwd,
                exposed_grad: 0.0,
                act_bytes: act,
                fwd_transient: 0.0,
                bwd_transient: 0.0,
                param_bytes: pm,
            },
            p2p_in: p2p,
        }
    }

    fn free_link(from: usize) -> P2pTransfer {
        P2pTransfer {
            from_stage: from,
            to_stage: from + 1,
            bytes_fwd: 0.0,
            bytes_bwd: 0.0,
            alpha: 0.0,
            beta: f64::INFINITY,
            streams: 1,
        }
    }

    #[test]
    fn stage_phases_decompose_the_intra_op_replay() {
        use crate::graph::models::{gpt2, Gpt2Cfg};
        use crate::layout::LayoutManager;
        use crate::solver::{solve, SolveOpts, SolverGraph};
        let g = gpt2(&Gpt2Cfg::mini());
        let mesh = DeviceMesh {
            shape: vec![2],
            devices: vec![0, 1],
            axis_alpha: vec![1e-6],
            axis_beta: vec![1e11],
        };
        let dev = DeviceModel::a100_80gb();
        let lm = LayoutManager::new(mesh.clone());
        let sg = SolverGraph::build(&g, &mesh, &dev, &lm);
        let sol = solve(
            &sg,
            1e13,
            SolveOpts { anneal_iters: 150, ..Default::default() },
        )
        .unwrap();
        let ep = crate::gen::lower(&g, &sg, &sol, &mesh, &lm, None);
        let ph = stage_phases(&g, &mesh, &ep, &dev).unwrap();
        let replay =
            crate::sim::exec::replay_exec(&g, &mesh, &ep, &dev).unwrap();
        // fwd + bwd + exposed grad IS the serialized intra-op replay —
        // the phase split only re-associates the same op durations
        let total = ph.fwd + ph.bwd + ph.exposed_grad;
        let rel = (total - replay.step_time).abs() / replay.step_time;
        assert!(
            rel < 1e-9,
            "phases {total} vs replay {}",
            replay.step_time
        );
        assert!(ph.param_bytes > 0.0 && ph.act_bytes > 0.0);
        assert!(ph.fwd > 0.0 && ph.bwd > 0.0);
    }

    #[test]
    fn single_stage_is_exactly_the_serial_step() {
        for nb in [1usize, 3, 8] {
            let t = replay_1f1b(&[spec(1.0, 2.0, 100.0, 10.0, None)], nb)
                .unwrap();
            // (fwd + bwd) split over B microbatches sums back exactly
            assert!(
                (t.step_time - 3.0).abs() < 1e-9,
                "B={nb}: {}",
                t.step_time
            );
            // one microbatch in flight: params + act/B
            assert!(
                (t.devices[0].peak_mem - (10.0 + 100.0 / nb as f64))
                    .abs()
                    < 1e-6
            );
        }
    }

    #[test]
    fn balanced_two_stage_pipeline_has_the_textbook_makespan() {
        // equal stages, free links: makespan = (B + S - 1) * (f+b)/B
        let stages = vec![
            spec(1.0, 1.0, 80.0, 5.0, None),
            spec(1.0, 1.0, 80.0, 5.0, Some(free_link(0))),
        ];
        let nb = 4;
        let t = replay_1f1b(&stages, nb).unwrap();
        let per_mb = 2.0 / nb as f64;
        let expect = (nb + 2 - 1) as f64 * per_mb;
        assert!(
            (t.step_time - expect).abs() < 1e-9,
            "got {}, want {expect}",
            t.step_time
        );
        // stage 0 holds min(S - 0, B) = 2 microbatches in flight,
        // stage 1 holds 1
        let act_mb = 80.0 / nb as f64;
        assert!(
            (t.devices[0].peak_mem - (5.0 + 2.0 * act_mb)).abs() < 1e-6,
            "stage0 peak {}",
            t.devices[0].peak_mem
        );
        assert!(
            (t.devices[1].peak_mem - (5.0 + act_mb)).abs() < 1e-6,
            "stage1 peak {}",
            t.devices[1].peak_mem
        );
    }

    #[test]
    fn deep_pipelines_never_deadlock() {
        for ns in 1..=5usize {
            for nb in 1..=6usize {
                let mut stages = vec![spec(0.6, 1.1, 10.0, 1.0, None)];
                for s in 1..ns {
                    stages.push(spec(
                        0.5 + s as f64 * 0.1,
                        1.0,
                        10.0,
                        1.0,
                        Some(free_link(s - 1)),
                    ));
                }
                let t = replay_1f1b(&stages, nb).unwrap_or_else(|e| {
                    panic!("S={ns} B={nb}: {e}")
                });
                assert!(t.step_time > 0.0);
                // every stage ends with all activations freed: final
                // resident memory equals its params
                for (s, d) in t.devices.iter().enumerate() {
                    let last = d.events.last().unwrap();
                    assert!(
                        (last.mem - 1.0).abs() < 1e-6,
                        "S={ns} B={nb} stage {s}: leaked {}",
                        last.mem
                    );
                }
            }
        }
    }

    #[test]
    fn in_flight_memory_is_bounded_by_min_depth_microbatches() {
        let ns = 4;
        for nb in [2usize, 3, 8] {
            let mut stages = vec![spec(1.0, 1.0, 100.0, 0.0, None)];
            for s in 1..ns {
                stages.push(spec(1.0, 1.0, 100.0, 0.0,
                                 Some(free_link(s - 1))));
            }
            let t = replay_1f1b(&stages, nb).unwrap();
            for (s, d) in t.devices.iter().enumerate() {
                let bound =
                    (ns - s).min(nb) as f64 * 100.0 / nb as f64;
                assert!(
                    d.peak_mem <= bound + 1e-6,
                    "B={nb} stage {s}: peak {} > bound {bound}",
                    d.peak_mem
                );
            }
        }
    }

    #[test]
    fn p2p_latency_slows_the_pipeline() {
        let mk = |alpha: f64| {
            vec![
                spec(1.0, 1.0, 0.0, 0.0, None),
                spec(
                    1.0,
                    1.0,
                    0.0,
                    0.0,
                    Some(P2pTransfer {
                        from_stage: 0,
                        to_stage: 1,
                        bytes_fwd: 1e6,
                        bytes_bwd: 1e6,
                        alpha,
                        beta: 1e9,
                        streams: 1,
                    }),
                ),
            ]
        };
        let fast = replay_1f1b(&mk(0.0), 4).unwrap();
        let slow = replay_1f1b(&mk(0.05), 4).unwrap();
        assert!(
            slow.step_time > fast.step_time,
            "latency must surface: {} vs {}",
            slow.step_time,
            fast.step_time
        );
    }

    #[test]
    fn rejects_malformed_stage_lists() {
        assert!(replay_1f1b(&[], 2).is_err());
        assert!(
            replay_1f1b(&[spec(1.0, 1.0, 0.0, 0.0, None)], 0).is_err()
        );
        // stage 1 without a boundary link
        let bad = vec![
            spec(1.0, 1.0, 0.0, 0.0, None),
            spec(1.0, 1.0, 0.0, 0.0, None),
        ];
        assert!(replay_1f1b(&bad, 2).is_err());
        // stage 0 with a spurious incoming link
        let bad =
            vec![spec(1.0, 1.0, 0.0, 0.0, Some(free_link(0)))];
        assert!(replay_1f1b(&bad, 2).is_err());
    }

    // -- schedule zoo -------------------------------------------------------

    #[test]
    fn schedule_parses_and_prints_canonically() {
        assert_eq!(Schedule::parse("1f1b").unwrap(), Schedule::OneF1B);
        assert_eq!(
            Schedule::parse("interleaved:3").unwrap(),
            Schedule::Interleaved { v: 3 }
        );
        for s in [Schedule::OneF1B, Schedule::Interleaved { v: 2 }] {
            assert_eq!(Schedule::parse(&s.name()).unwrap(), s);
        }
        assert!(Schedule::parse("interleaved:1").is_err());
        assert!(Schedule::parse("interleaved:x").is_err());
        assert!(Schedule::parse("gpipe").is_err());
        assert_eq!(Schedule::default(), Schedule::OneF1B);
        // interleaving needs B % S == 0 and a real pipeline
        let il = Schedule::Interleaved { v: 2 };
        assert!(il.feasible_for(2, 4));
        assert!(!il.feasible_for(2, 3));
        assert!(!il.feasible_for(1, 4));
        assert!(Schedule::OneF1B.feasible_for(1, 1));
    }

    #[test]
    fn interleaved_two_stage_has_the_textbook_makespan() {
        // equal stages, free links, v=2: the bubble shrinks to
        // (S-1)*(f+b)_mb / v while the steady span stays B*(f+b)_mb
        let stages = vec![
            spec(2.0, 2.0, 80.0, 5.0, None),
            spec(2.0, 2.0, 80.0, 5.0, Some(free_link(0))),
        ];
        let (nb, v) = (2usize, 2usize);
        let t = replay_interleaved(&stages, nb, v).unwrap();
        let per_mb = 2.0; // f_mb + b_mb = 2.0/2 + 2.0/2 per direction
        let expect =
            nb as f64 * per_mb + per_mb * (2 - 1) as f64 / v as f64;
        assert!(
            (t.step_time - expect).abs() < 1e-9,
            "got {}, want {expect}",
            t.step_time
        );
        // and it beats the non-interleaved bubble at the same B
        let base = replay_1f1b(&stages, nb).unwrap();
        assert!(t.step_time < base.step_time - 1e-9);
        // stage 0's all-warmup schedule holds the full chunk complement
        let act_c = 80.0 / (nb * v) as f64;
        let bound = Schedule::Interleaved { v }.in_flight_bound(2, 0, nb)
            as f64
            * v as f64
            * act_c;
        assert!(
            (t.devices[0].peak_mem - (5.0 + bound)).abs() < 1e-6,
            "stage0 peak {}",
            t.devices[0].peak_mem
        );
    }

    #[test]
    fn interleaved_never_deadlocks_leaks_or_blows_the_ledger() {
        for ns in 1..=4usize {
            for v in [2usize, 3] {
                for mult in [1usize, 2, 4] {
                    let nb = ns * mult;
                    let mut stages =
                        vec![spec(0.8, 1.3, 12.0, 1.0, None)];
                    for s in 1..ns {
                        stages.push(spec(
                            0.7 + s as f64 * 0.1,
                            1.1,
                            12.0,
                            1.0,
                            Some(free_link(s - 1)),
                        ));
                    }
                    let t = replay_interleaved(&stages, nb, v)
                        .unwrap_or_else(|e| {
                            panic!("S={ns} B={nb} v={v}: {e}")
                        });
                    assert!(t.step_time > 0.0);
                    let sched = Schedule::Interleaved { v };
                    for (s, d) in t.devices.iter().enumerate() {
                        // all activations freed at the end
                        let last = d.events.last().unwrap();
                        assert!(
                            (last.mem - 1.0).abs() < 1e-6,
                            "S={ns} B={nb} v={v} s{s}: leaked {}",
                            last.mem
                        );
                        // ledger peak within the schedule's ramp bound
                        let act_c = 12.0 / (nb * v) as f64;
                        let chunks = (sched
                            .in_flight_bound(ns, s, nb)
                            * v) as f64;
                        let bound = 1.0
                            + chunks * act_c
                            + stages[s].phases.fwd_transient
                                / nb as f64;
                        assert!(
                            d.peak_mem <= bound + 1e-6,
                            "S={ns} B={nb} v={v} s{s}: peak {} > \
                             bound {bound}",
                            d.peak_mem
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn interleaved_bubble_never_exceeds_1f1b_at_equal_b() {
        for ns in 2..=4usize {
            for mult in [1usize, 2, 3] {
                let nb = ns * mult;
                let mut stages = vec![spec(1.0, 1.0, 10.0, 1.0, None)];
                for s in 1..ns {
                    stages.push(spec(
                        1.0,
                        1.0,
                        10.0,
                        1.0,
                        Some(free_link(s - 1)),
                    ));
                }
                let base = replay_1f1b(&stages, nb).unwrap();
                for v in [2usize, 3] {
                    let il =
                        replay_interleaved(&stages, nb, v).unwrap();
                    assert!(
                        il.step_time <= base.step_time + 1e-9,
                        "S={ns} B={nb} v={v}: interleaved {} > 1f1b {}",
                        il.step_time,
                        base.step_time
                    );
                }
            }
        }
    }

    #[test]
    fn interleaved_pays_for_the_extra_p2p_crossings() {
        // a costly boundary is crossed v times per microbatch, so the
        // replay's comm share must grow with v
        let mk = || {
            vec![
                spec(1.0, 1.0, 0.0, 0.0, None),
                spec(
                    1.0,
                    1.0,
                    0.0,
                    0.0,
                    Some(P2pTransfer {
                        from_stage: 0,
                        to_stage: 1,
                        bytes_fwd: 4e6,
                        bytes_bwd: 4e6,
                        alpha: 0.01,
                        beta: 1e9,
                        streams: 1,
                    }),
                ),
            ]
        };
        let base = replay_1f1b(&mk(), 4).unwrap();
        let il = replay_interleaved(&mk(), 4, 2).unwrap();
        let count = |t: &SimTrace| {
            t.devices[0]
                .events
                .iter()
                .filter(|e| e.kind == EventKind::Comm)
                .count()
        };
        assert!(
            count(&il) > count(&base),
            "v=2 must post more boundary rendezvous ({} vs {})",
            count(&il),
            count(&base)
        );
    }

    #[test]
    fn interleaved_rejects_bad_shapes() {
        let stages = vec![
            spec(1.0, 1.0, 0.0, 0.0, None),
            spec(1.0, 1.0, 0.0, 0.0, Some(free_link(0))),
        ];
        // B not divisible by S
        assert!(replay_interleaved(&stages, 3, 2).is_err());
        // v < 2 is not an interleaved schedule
        assert!(replay_interleaved(&stages, 4, 1).is_err());
        assert!(replay_interleaved(&[], 2, 2).is_err());
    }

    #[test]
    fn replay_schedule_dispatches_both_ways() {
        let stages = vec![
            spec(1.0, 1.0, 8.0, 1.0, None),
            spec(1.0, 1.0, 8.0, 1.0, Some(free_link(0))),
        ];
        let a = replay_schedule(&stages, 4, Schedule::OneF1B).unwrap();
        let b = replay_1f1b(&stages, 4).unwrap();
        assert_eq!(a.step_time, b.step_time);
        let c = replay_schedule(
            &stages,
            4,
            Schedule::Interleaved { v: 2 },
        )
        .unwrap();
        let d = replay_interleaved(&stages, 4, 2).unwrap();
        assert_eq!(c.step_time, d.step_time);
    }

    #[test]
    fn in_flight_bound_degenerates_to_1f1b_at_v1() {
        for ns in 1..=4usize {
            for s in 0..ns {
                for nb in [1usize, 2, 8] {
                    assert_eq!(
                        Schedule::OneF1B.in_flight_bound(ns, s, nb),
                        (ns - s).min(nb)
                    );
                }
            }
        }
        // deeper ramp for earlier stages, never past the chunk total
        let sched = Schedule::Interleaved { v: 2 };
        assert!(
            sched.in_flight_bound(4, 0, 8)
                >= sched.in_flight_bound(4, 3, 8)
        );
        assert!(sched.in_flight_bound(2, 0, 2) <= 2);
    }

    // -- step-time clamp (sub-epsilon float negatives) ----------------------

    #[test]
    fn zero_cost_stages_replay_to_exactly_zero() {
        let stages = vec![
            spec(0.0, 0.0, 0.0, 0.0, None),
            spec(0.0, 0.0, 0.0, 0.0, Some(free_link(0))),
        ];
        let t = replay_1f1b(&stages, 4).unwrap();
        assert_eq!(t.step_time, 0.0);
        let t = replay_interleaved(&stages, 4, 2).unwrap();
        assert_eq!(t.step_time, 0.0);
    }

    #[test]
    fn step_time_clamp_tolerates_only_sub_epsilon_negatives() {
        let mk = |st: f64| SimTrace {
            mesh_shape: vec![1],
            analytic: false,
            step_time: st,
            peak_mem: 0.0,
            param_mem: 0.0,
            compute_time: 1.0,
            comm_time: 0.0,
            recompute_time: 0.0,
            exposed_grad_time: 0.0,
            devices: Vec::new(),
        };
        // a float-accumulation hair below zero is clamped ...
        let t = clamp_step_time(mk(-1e-12), "test").unwrap();
        assert_eq!(t.step_time, 0.0);
        // ... a genuinely negative time still bails
        assert!(clamp_step_time(mk(-0.5), "test").is_err());
        // and non-negative times pass through untouched
        let t = clamp_step_time(mk(2.5), "test").unwrap();
        assert_eq!(t.step_time, 2.5);
    }
}
