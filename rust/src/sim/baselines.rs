//! Manually-designed parallelism baselines (§7 / Table 4): DDP, Megatron
//! 1-D TP, Optimus 2-D TP, and 3-D TP, costed analytically on the detected
//! cluster — including their blindness to the fine-grained topology, which
//! is exactly what the paper's Table 4 exposes.

use crate::cluster::ClusterInfo;
use crate::graph::models::Gpt2Cfg;
use crate::graph::{Graph, op::Op};
use crate::profiler::{cost::node_cost, GraphProfile};

use super::device::DeviceModel;

/// Bytes of persistent model data per parameter under the paper's
/// training recipe (mixed-precision Adam: fp16 param + grad, fp32 master
/// + two moments) — what makes DDP OOM as the problem grows.
pub const MODEL_DATA_BYTES_PER_PARAM: f64 = 16.0;

/// Fraction of device memory actually usable for model data + activations
/// (allocator fragmentation, cuDNN/cuBLAS workspaces, CUDA context).
pub const USABLE_MEM_FRACTION: f64 = 0.90;

#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub n_devices: usize,
    /// Per-iteration wall time (seconds).
    pub iter_time: f64,
    /// Aggregate achieved PFLOPS (the Table-4 metric).
    pub pflops: f64,
    pub mem_per_device: f64,
    pub feasible: bool,
    pub note: String,
}

impl SimReport {
    fn oom(name: &str, n: usize, mem: f64, note: &str) -> SimReport {
        SimReport {
            name: name.into(),
            n_devices: n,
            iter_time: f64::INFINITY,
            pflops: 0.0,
            mem_per_device: mem,
            feasible: false,
            note: note.into(),
        }
    }

    fn na(name: &str, n: usize, note: &str) -> SimReport {
        SimReport {
            name: name.into(),
            n_devices: n,
            iter_time: f64::INFINITY,
            pflops: 0.0,
            mem_per_device: 0.0,
            feasible: false,
            note: note.into(),
        }
    }
}

/// Serial single-device step time under the same per-node roofline the
/// planner uses (GEMMs at tensor-core efficiency, everything else
/// memory-bound) — so baselines and "ours" are costed identically.
pub fn serial_compute_time(g: &Graph, dev: &DeviceModel) -> f64 {
    g.nodes
        .iter()
        .map(|n| {
            if matches!(n.op, Op::Placeholder(_) | Op::Output) {
                return 0.0;
            }
            let c = node_cost(g, n.id);
            dev.kernel_time(
                c.total_flops(),
                3.0 * (c.fwd_in + c.fwd_out) as f64,
                n.op.compute_intensive(),
            )
        })
        .sum()
}

/// Ring all-reduce time over a device group at its weakest-link bandwidth.
fn all_reduce_time(info: &ClusterInfo, group: &[usize], bytes: f64) -> f64 {
    let n = group.len() as f64;
    if n <= 1.0 {
        return 0.0;
    }
    let bw = info.bus_bandwidth(group);
    let alpha = info.group_alpha(group);
    2.0 * (n - 1.0) / n * bytes / bw + 2.0 * (n - 1.0) * alpha
}

fn report(
    name: &str,
    n: usize,
    compute: f64,
    comm: f64,
    bwd_compute: f64,
    overlappable_comm: f64,
    mem: f64,
    dev: &DeviceModel,
    prof: &GraphProfile,
    note: &str,
) -> SimReport {
    if mem > dev.memory * USABLE_MEM_FRACTION {
        return SimReport::oom(name, n, mem, "out of memory");
    }
    // gradient-sync communication overlaps with backward compute (§7:
    // "communication ... could overlap with the backward computation")
    let hidden = overlappable_comm.min(0.7 * bwd_compute);
    let iter = compute + comm - hidden;
    SimReport {
        name: name.into(),
        n_devices: n,
        iter_time: iter,
        pflops: prof.total_flops() / iter / 1e15,
        mem_per_device: mem,
        feasible: true,
        note: note.into(),
    }
}

/// Pure data parallelism: batch sharded, full model replicated, one big
/// gradient all-reduce over every device.
pub fn ddp(
    cfg: &Gpt2Cfg,
    g: &Graph,
    prof: &GraphProfile,
    info: &ClusterInfo,
    dev: &DeviceModel,
) -> SimReport {
    let n = info.n;
    let all: Vec<usize> = (0..n).collect();
    let p_bytes = prof.model_bytes as f64;
    let compute = serial_compute_time(g, dev) / n as f64;
    let comm = all_reduce_time(info, &all, p_bytes);
    let n_params = p_bytes / 4.0;
    let mem = MODEL_DATA_BYTES_PER_PARAM * n_params
        + prof.saved_activation as f64 / n as f64;
    let bwd = compute * 2.0 / 3.0;
    let _ = cfg;
    report("DDP", n, compute, comm, bwd, comm, mem, dev, prof,
           "batch-sharded, model replicated")
}

/// Megatron-LM 1-D tensor parallelism: weights column/row split across
/// ALL devices; 4 activation all-reduces per layer per iteration (2 fwd +
/// 2 bwd), each over the full device group — the bottleneck link gates
/// them (§7 "1D TP").
pub fn megatron_1d(
    cfg: &Gpt2Cfg,
    g: &Graph,
    prof: &GraphProfile,
    info: &ClusterInfo,
    dev: &DeviceModel,
) -> SimReport {
    let n = info.n;
    let all: Vec<usize> = (0..n).collect();
    let act_bytes = (cfg.batch * cfg.seq * cfg.d_model * 4) as f64;
    let comm =
        cfg.n_layer as f64 * 4.0 * all_reduce_time(info, &all, act_bytes);
    let compute = serial_compute_time(g, dev) / n as f64;
    // per-device: embeddings replicated, block weights 1/n
    let emb = (cfg.vocab + cfg.seq) as f64 * cfg.d_model as f64 * 4.0;
    let blocks = prof.model_bytes as f64 - emb;
    let mem = MODEL_DATA_BYTES_PER_PARAM / 4.0
        * (emb + blocks / n as f64)
        + prof.saved_activation as f64 / n as f64;
    report("Megatron-1D", n, compute, comm, compute * 2.0 / 3.0, 0.0, mem,
           dev, prof, "activation all-reduce crosses the weakest link")
}

/// Optimus 2-D TP: requires n = q^2. SUMMA-style: per layer ~6 collective
/// phases of activation shards over rows/cols of the naive q×q grid.
pub fn optimus_2d(
    cfg: &Gpt2Cfg,
    g: &Graph,
    prof: &GraphProfile,
    info: &ClusterInfo,
    dev: &DeviceModel,
) -> SimReport {
    let n = info.n;
    let q = (n as f64).sqrt().round() as usize;
    if q * q != n || q < 2 {
        return SimReport::na(
            "Optimus-2D",
            n,
            "requires a square device count",
        );
    }
    // naive assignment: row i = devices [i*q, (i+1)*q)
    let rows: Vec<Vec<usize>> =
        (0..q).map(|i| (i * q..(i + 1) * q).collect()).collect();
    let cols: Vec<Vec<usize>> =
        (0..q).map(|j| (0..q).map(|i| i * q + j).collect()).collect();
    let shard_bytes =
        (cfg.batch * cfg.seq * cfg.d_model * 4) as f64 / q as f64;
    let worst_row = rows
        .iter()
        .map(|g| all_reduce_time(info, g, shard_bytes))
        .fold(0.0, f64::max);
    let worst_col = cols
        .iter()
        .map(|g| all_reduce_time(info, g, shard_bytes))
        .fold(0.0, f64::max);
    let comm = cfg.n_layer as f64 * 3.0 * (worst_row + worst_col);
    let compute = serial_compute_time(g, dev) / n as f64;
    let mem = MODEL_DATA_BYTES_PER_PARAM / 4.0 * prof.model_bytes as f64
        / n as f64
        + prof.saved_activation as f64 / n as f64;
    report("Optimus-2D", n, compute, comm, compute * 2.0 / 3.0, 0.0, mem,
           dev, prof, "q x q SUMMA grid, naive device assignment")
}

/// 3-D TP: requires n = c^3; collective phases over the three axes of the
/// naive c×c×c grid with c-sized groups.
pub fn tp_3d(
    cfg: &Gpt2Cfg,
    g: &Graph,
    prof: &GraphProfile,
    info: &ClusterInfo,
    dev: &DeviceModel,
) -> SimReport {
    let n = info.n;
    let c = (n as f64).cbrt().round() as usize;
    if c * c * c != n || c < 2 {
        return SimReport::na("3D-TP", n, "requires a cubic device count");
    }
    let shard_bytes = (cfg.batch * cfg.seq * cfg.d_model * 4) as f64
        / (c * c) as f64;
    // axis groups under naive assignment, stride 1 / c / c^2
    let mut worst = 0.0f64;
    for stride in [1usize, c, c * c] {
        for start in 0..n {
            if (start / stride) % c != 0 {
                continue;
            }
            let group: Vec<usize> =
                (0..c).map(|k| start + k * stride).collect();
            if group.iter().all(|&d| d < n) {
                worst = worst
                    .max(all_reduce_time(info, &group, shard_bytes));
            }
        }
    }
    let comm = cfg.n_layer as f64 * 8.0 * worst;
    let compute = serial_compute_time(g, dev) / n as f64;
    let mem = MODEL_DATA_BYTES_PER_PARAM / 4.0 * prof.model_bytes as f64
        / n as f64
        + prof.saved_activation as f64 / n as f64;
    report("3D-TP", n, compute, comm, compute * 2.0 / 3.0, 0.0, mem, dev,
           prof, "c^3 grid, naive device assignment")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{detect, SimCluster};
    use crate::graph::models::gpt2;
    use crate::profiler::profile;

    fn setup(n: usize, exp: &str)
             -> (Gpt2Cfg, Graph, GraphProfile, ClusterInfo) {
        let cfg = Gpt2Cfg::paper(exp);
        let g = gpt2(&cfg);
        let prof = profile(&g);
        (cfg, g, prof, detect(&SimCluster::fig5_prefix(n), 1))
    }

    #[test]
    fn ddp_ooms_as_problem_grows() {
        let dev = DeviceModel::a100_80gb();
        let (cfg, g, prof, info) = setup(4, "gamma");
        let r = ddp(&cfg, &g, &prof, &info, &dev);
        assert!(!r.feasible, "gamma (4B params) must OOM under DDP: {:.1} GB", r.mem_per_device / 1e9);
        let (cfg_a, g_a, prof_a, info_a) = setup(1, "alpha");
        assert!(ddp(&cfg_a, &g_a, &prof_a, &info_a, &dev).feasible);
    }

    #[test]
    fn validity_rules_match_paper() {
        let (cfg, g, prof, info) = setup(8, "delta");
        let dev = DeviceModel::a100_80gb();
        assert!(!optimus_2d(&cfg, &g, &prof, &info, &dev).feasible);
        assert!(tp_3d(&cfg, &g, &prof, &info, &dev).feasible); // 8 == 2^3
        let (cfg4, g4, prof4, info4) = setup(4, "gamma");
        assert!(optimus_2d(&cfg4, &g4, &prof4, &info4, &dev).feasible);
        assert!(!tp_3d(&cfg4, &g4, &prof4, &info4, &dev).feasible);
    }

    #[test]
    fn megatron_pflops_degrades_with_scale() {
        let dev = DeviceModel::a100_80gb();
        // per-GPU PFLOPS should fall as more (worse-connected) gpus join
        let per_gpu: Vec<f64> = [("beta", 2), ("gamma", 4), ("delta", 8)]
            .iter()
            .map(|(e, n)| {
                let (cfg, g, prof, info) = setup(*n, e);
                let r = megatron_1d(&cfg, &g, &prof, &info, &dev);
                r.pflops / *n as f64
            })
            .collect();
        assert!(per_gpu[0] > per_gpu[1], "{per_gpu:?}");
        assert!(per_gpu[1] > per_gpu[2], "{per_gpu:?}");
    }
}
