//! `SimTrace` — the artifact produced by the discrete-event executor
//! ([`sim::exec`](super::exec)): per-device timelines, the byte-accurate
//! memory ledger's peak, and the simulated step time.
//!
//! Serialization goes through [`util::json`](crate::util::json) like every
//! other artifact (the `Artifact` trait impl lives in `api::artifacts`,
//! next to the other kind-tagged formats, because the trait is defined
//! there). The JSON writer is canonical (sorted keys, shortest-roundtrip
//! floats), so equal traces always serialize byte-identically — the
//! property the golden-trace regression fixtures rely on.

use anyhow::{anyhow, bail, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// What a timeline event represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Forward compute of one linearized stage.
    FwdCompute,
    /// Backward compute of one linearized stage.
    BwdCompute,
    /// Forward re-execution of a checkpointed stage during backward.
    Recompute,
    /// A collective on the critical path (correctness / resharding).
    Comm,
    /// Gradient-sync communication not hidden behind backward compute.
    GradSync,
}

impl EventKind {
    pub fn name(self) -> &'static str {
        match self {
            EventKind::FwdCompute => "fwd",
            EventKind::BwdCompute => "bwd",
            EventKind::Recompute => "recompute",
            EventKind::Comm => "comm",
            EventKind::GradSync => "grad-sync",
        }
    }

    pub fn parse(t: &str) -> Result<EventKind> {
        Ok(match t {
            "fwd" => EventKind::FwdCompute,
            "bwd" => EventKind::BwdCompute,
            "recompute" => EventKind::Recompute,
            "comm" => EventKind::Comm,
            "grad-sync" => EventKind::GradSync,
            other => bail!("unknown trace event kind '{other}'"),
        })
    }
}

/// One interval on a device's timeline.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub label: String,
    /// Start / end of the interval, seconds since step start.
    pub t0: f64,
    pub t1: f64,
    /// Absolute resident memory (params + activations) when the event
    /// completed, bytes. Transient highs inside the event feed the peak
    /// but are not recorded per event.
    pub mem: f64,
}

/// Timeline + ledger summary of one simulated device.
#[derive(Debug, Clone)]
pub struct DeviceTimeline {
    /// Logical device index (row-major position in the mesh).
    pub device: usize,
    /// Highest resident memory observed on this device, bytes.
    pub peak_mem: f64,
    pub events: Vec<TraceEvent>,
}

/// Full replay result: what `automap verify` inspects and the golden
/// fixtures snapshot.
#[derive(Debug, Clone)]
pub struct SimTrace {
    pub mesh_shape: Vec<usize>,
    /// True when the plan came from an analytic (closed-form) backend and
    /// the replay is a single aggregate step, not a real schedule.
    pub analytic: bool,
    /// Wall time of one training iteration, seconds (max over devices).
    pub step_time: f64,
    /// Peak resident memory over all devices, bytes.
    pub peak_mem: f64,
    /// Parameter + gradient memory resident for the whole step, bytes.
    pub param_mem: f64,
    /// Per-category totals for one device's queue (SPMD: identical on
    /// every device), seconds.
    pub compute_time: f64,
    pub comm_time: f64,
    pub recompute_time: f64,
    pub exposed_grad_time: f64,
    pub devices: Vec<DeviceTimeline>,
}

impl SimTrace {
    /// Simulated-minus-recorded step-time drift, relative to `predicted`.
    pub fn drift(&self, predicted: f64) -> f64 {
        if predicted <= 0.0 {
            return 0.0;
        }
        (self.step_time - predicted) / predicted
    }

    pub fn to_json_value(&self) -> Json {
        let devices = arr(self
            .devices
            .iter()
            .map(|d| {
                obj(vec![
                    ("device", num(d.device as f64)),
                    ("peak_mem", num(d.peak_mem)),
                    (
                        "events",
                        arr(d
                            .events
                            .iter()
                            .map(|e| {
                                obj(vec![
                                    ("kind", s(e.kind.name())),
                                    ("label", s(&e.label)),
                                    ("t0", num(e.t0)),
                                    ("t1", num(e.t1)),
                                    ("mem", num(e.mem)),
                                ])
                            })
                            .collect()),
                    ),
                ])
            })
            .collect());
        obj(vec![
            (
                "mesh_shape",
                arr(self
                    .mesh_shape
                    .iter()
                    .map(|&x| num(x as f64))
                    .collect()),
            ),
            ("analytic", Json::Bool(self.analytic)),
            ("step_time", num(self.step_time)),
            ("peak_mem", num(self.peak_mem)),
            ("param_mem", num(self.param_mem)),
            ("compute_time", num(self.compute_time)),
            ("comm_time", num(self.comm_time)),
            ("recompute_time", num(self.recompute_time)),
            ("exposed_grad_time", num(self.exposed_grad_time)),
            ("devices", devices),
        ])
    }

    pub fn from_json_value(v: &Json) -> Result<SimTrace> {
        let f = |k: &str| -> Result<f64> {
            v.get(k)
                .as_f64()
                .ok_or_else(|| anyhow!("trace.{k} must be a number"))
        };
        let mut devices = Vec::new();
        for d in v
            .get("devices")
            .as_arr()
            .ok_or_else(|| anyhow!("trace.devices must be an array"))?
        {
            let mut events = Vec::new();
            for e in d
                .get("events")
                .as_arr()
                .ok_or_else(|| anyhow!("device.events must be an array"))?
            {
                events.push(TraceEvent {
                    kind: EventKind::parse(
                        e.get("kind")
                            .as_str()
                            .ok_or_else(|| anyhow!("event.kind missing"))?,
                    )?,
                    label: e
                        .get("label")
                        .as_str()
                        .ok_or_else(|| anyhow!("event.label missing"))?
                        .to_string(),
                    t0: e
                        .get("t0")
                        .as_f64()
                        .ok_or_else(|| anyhow!("event.t0 missing"))?,
                    t1: e
                        .get("t1")
                        .as_f64()
                        .ok_or_else(|| anyhow!("event.t1 missing"))?,
                    mem: e
                        .get("mem")
                        .as_f64()
                        .ok_or_else(|| anyhow!("event.mem missing"))?,
                });
            }
            devices.push(DeviceTimeline {
                device: d
                    .get("device")
                    .as_usize()
                    .ok_or_else(|| anyhow!("device.device missing"))?,
                peak_mem: d
                    .get("peak_mem")
                    .as_f64()
                    .ok_or_else(|| anyhow!("device.peak_mem missing"))?,
                events,
            });
        }
        Ok(SimTrace {
            mesh_shape: v
                .get("mesh_shape")
                .usize_vec()
                .ok_or_else(|| anyhow!("trace.mesh_shape missing"))?,
            analytic: v.get("analytic").as_bool().unwrap_or(false),
            step_time: f("step_time")?,
            peak_mem: f("peak_mem")?,
            param_mem: f("param_mem")?,
            compute_time: f("compute_time")?,
            comm_time: f("comm_time")?,
            recompute_time: f("recompute_time")?,
            exposed_grad_time: f("exposed_grad_time")?,
            devices,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimTrace {
        SimTrace {
            mesh_shape: vec![2],
            analytic: false,
            step_time: 0.25,
            peak_mem: 1536.0,
            param_mem: 512.0,
            compute_time: 0.2,
            comm_time: 0.05,
            recompute_time: 0.0,
            exposed_grad_time: 0.0,
            devices: vec![DeviceTimeline {
                device: 0,
                peak_mem: 1536.0,
                events: vec![TraceEvent {
                    kind: EventKind::FwdCompute,
                    label: "fwd s0".into(),
                    t0: 0.0,
                    t1: 0.2,
                    mem: 1024.0,
                }],
            }],
        }
    }

    #[test]
    fn trace_roundtrips_through_json() {
        let t = sample();
        let back = SimTrace::from_json_value(&t.to_json_value()).unwrap();
        assert_eq!(back.mesh_shape, t.mesh_shape);
        assert_eq!(back.step_time, t.step_time);
        assert_eq!(back.peak_mem, t.peak_mem);
        assert_eq!(back.devices.len(), 1);
        assert_eq!(back.devices[0].events[0].label, "fwd s0");
        assert_eq!(back.devices[0].events[0].kind, EventKind::FwdCompute);
        // canonical writer: a second serialization is byte-identical
        assert_eq!(
            t.to_json_value().to_string(),
            back.to_json_value().to_string()
        );
    }

    #[test]
    fn event_kind_names_roundtrip() {
        for k in [
            EventKind::FwdCompute,
            EventKind::BwdCompute,
            EventKind::Recompute,
            EventKind::Comm,
            EventKind::GradSync,
        ] {
            assert_eq!(EventKind::parse(k.name()).unwrap(), k);
        }
        assert!(EventKind::parse("warp").is_err());
    }
}
