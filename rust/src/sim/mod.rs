//! Execution simulator: device roofline model and the manually-designed
//! baselines the paper compares against (Table 4).

pub mod baselines;
pub mod device;

pub use baselines::{ddp, megatron_1d, optimus_2d, tp_3d, SimReport};
pub use device::DeviceModel;
