//! Execution simulation: the per-accelerator roofline model, the
//! manually-designed Table-4 baselines, and the discrete-event plan
//! executor (`exec`) that replays lowered plans tick-by-tick as a
//! cost-model-free oracle.

pub mod baselines;
pub mod device;
pub mod exec;
pub mod pipeline;
pub mod trace;

pub use baselines::{ddp, megatron_1d, optimus_2d, tp_3d, SimReport};
pub use device::DeviceModel;
pub use exec::{exposed_grad, replay_analytic, replay_exec, run_programs,
               simulate_schedule, validate_exec, SimOp, OVERLAP_FRAC};
pub use pipeline::{replay_1f1b, replay_interleaved, replay_schedule,
                   stage_phases, PipelineStageSpec, Schedule,
                   StagePhases};
pub use trace::{DeviceTimeline, EventKind, SimTrace, TraceEvent};
