//! Device compute model: a roofline for one accelerator, used to turn
//! profiled FLOPs/bytes into estimated execution time.

#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    /// Peak dense-matmul throughput (FLOP/s) for the training dtype.
    pub peak_flops: f64,
    /// HBM bandwidth (bytes/s).
    pub hbm_bw: f64,
    /// Achievable fraction of peak for large GEMMs (efficiency knob).
    pub gemm_efficiency: f64,
    /// Achievable fraction of peak for non-GEMM (vector) work.
    pub vector_efficiency: f64,
    /// Device memory capacity in bytes (the solver's default budget).
    pub memory: f64,
    /// Fixed per-kernel launch overhead (seconds).
    pub kernel_overhead: f64,
}

impl DeviceModel {
    /// NVIDIA A100-80GB, fp16/bf16 tensor-core training (paper testbed).
    pub fn a100_80gb() -> DeviceModel {
        DeviceModel {
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            gemm_efficiency: 0.55,
            vector_efficiency: 0.08,
            memory: 80e9,
            kernel_overhead: 6e-6,
        }
    }

    /// This device derated to `scale`× the reference class: peak FLOPs
    /// and HBM bandwidth shrink together (older generations are slower
    /// on both rooflines), capacity and launch overhead stay put. Used
    /// to price a stage pinned to a slower device class in a
    /// mixed-generation cluster.
    pub fn scaled(&self, scale: f64) -> DeviceModel {
        assert!(scale > 0.0 && scale.is_finite(), "bad scale {scale}");
        DeviceModel {
            peak_flops: self.peak_flops * scale,
            hbm_bw: self.hbm_bw * scale,
            ..*self
        }
    }

    /// Roofline time for a kernel doing `flops` work over `bytes` of
    /// traffic: max(compute-bound, memory-bound) + launch overhead.
    pub fn kernel_time(&self, flops: f64, bytes: f64, is_gemm: bool) -> f64 {
        let eff = if is_gemm {
            self.gemm_efficiency
        } else {
            self.vector_efficiency
        };
        let compute = flops / (self.peak_flops * eff);
        let mem = bytes / self.hbm_bw;
        compute.max(mem) + self.kernel_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn big_gemm_is_compute_bound() {
        let d = DeviceModel::a100_80gb();
        // 4096^3 GEMM: 137 GFLOP over ~200 MB
        let t = d.kernel_time(2.0 * 4096f64.powi(3), 3.0 * 4096.0 * 4096.0 * 2.0, true);
        let ideal = 2.0 * 4096f64.powi(3) / (312e12 * 0.55);
        assert!((t / ideal - 1.0).abs() < 0.05);
    }

    #[test]
    fn elementwise_is_memory_bound() {
        let d = DeviceModel::a100_80gb();
        // gelu on 1 GB: 10 flops/elem but 2 GB of traffic
        let t = d.kernel_time(10.0 * 2.5e8, 2e9, false);
        assert!((t - 1e-3).abs() / 1e-3 < 0.1, "t = {t}");
    }

    #[test]
    fn overhead_floors_tiny_kernels() {
        let d = DeviceModel::a100_80gb();
        assert!(d.kernel_time(1.0, 1.0, false) >= 6e-6);
    }

    #[test]
    fn scaled_derates_both_rooflines_but_not_memory() {
        let d = DeviceModel::a100_80gb();
        let half = d.scaled(0.5);
        assert_eq!(half.peak_flops, d.peak_flops * 0.5);
        assert_eq!(half.hbm_bw, d.hbm_bw * 0.5);
        assert_eq!(half.memory, d.memory);
        assert_eq!(d.scaled(1.0).peak_flops, d.peak_flops);
        let big = 2.0 * 4096f64.powi(3);
        let bytes = 3.0 * 4096.0 * 4096.0 * 2.0;
        assert!(
            half.kernel_time(big, bytes, true)
                > 1.9 * d.kernel_time(big, bytes, true)
        );
    }
}
