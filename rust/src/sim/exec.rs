//! Discrete-event plan executor (`sim::exec`): replays a lowered
//! [`ExecutionPlan`](crate::gen::ExecutionPlan) tick-by-tick across
//! simulated devices and reports what *actually executing* the schedule
//! would cost — per-device timelines, a byte-accurate memory ledger, and
//! the true step time — independently of the analytic predictions the
//! solver stack was built from.
//!
//! The paper's compilation flow trusts a roofline cost model plus the
//! rotor DP; Alpa-style systems check such predictions against measured
//! step time and peak memory. Offline we cannot measure, but we *can*
//! deterministically simulate: every device gets a program of compute
//! segments and collectives, collectives rendezvous across their mesh
//! group (detecting mismatched signatures and deadlocks), and a ledger
//! tracks parameters, retained activations, checkpoint recomputation, and
//! transient `o_f`/`o_b` overheads at every instant.
//!
//! Three layers:
//!
//! 1. [`run_programs`] — the generic event loop over per-device
//!    [`SimOp`] programs (rendezvous, mismatch/deadlock detection, the
//!    ledger). Usable standalone for hand-built programs.
//! 2. [`simulate_schedule`] — replay a rotor stage chain (+ optional
//!    [`RotorSolution`]) on one device; what the property tests compare
//!    against `RotorSolver`'s predictions.
//! 3. [`replay_exec`] — reconstruct the full per-device schedule from a
//!    lowered plan (decisions → stage times, comm inserts → collectives,
//!    ckpt blocks → recompute phases) and run it. `automap verify` and
//!    the `sim-measure` backend sit on this.
//!
//! Modeling contract (kept deliberately identical to the planner's cost
//! accounting so the simulator is a *check*, not a second guess):
//! checkpointed blocks re-execute their forward once, keeping
//! intermediates (`torch.utils.checkpoint` semantics — the code the §6
//! generator emits); resharding collectives run once on the forward
//! sweep; gradient-sync overlaps backward compute at [`OVERLAP_FRAC`]
//! efficiency with only the exposed remainder serialized. Under that
//! contract the simulated step time is bounded by the rotor DP's
//! prediction (the DP may additionally nest recomputation), which is what
//! the differential oracle asserts.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::ckpt::{build_stages, common_nodes, linearize, Block, NodeTimes,
                  RotorSolution, Stage};
use crate::cluster::DeviceMesh;
use crate::gen::{CommInsert, CommReason, ExecutionPlan};
use crate::graph::op::Op;
use crate::graph::Graph;
use crate::sim::DeviceModel;
use crate::util::json::StableHasher;

pub use super::trace::{DeviceTimeline, EventKind, SimTrace, TraceEvent};

/// Fraction of backward compute that can hide gradient-sync collectives
/// (§7: the DP all-reduce overlaps the backward sweep). The planner's
/// candidate ranking uses the same constant — keep them in sync.
pub const OVERLAP_FRAC: f64 = 0.7;

/// Gradient-sync time left exposed after overlapping with backward
/// compute — the single definition shared by the planner's candidate
/// ranking and the replayer, so predicted and simulated step times
/// apply one overlap model.
pub fn exposed_grad(grad_total: f64, bwd_compute: f64) -> f64 {
    (grad_total - OVERLAP_FRAC * bwd_compute).max(0.0)
}

// ---------------------------------------------------------------------------
// programs

/// One instruction of a device's program.
#[derive(Debug, Clone)]
pub enum SimOp {
    /// Local work on the device's compute queue.
    Compute {
        kind: EventKind,
        label: String,
        secs: f64,
        /// Bytes retained from the start of this op onward.
        alloc: f64,
        /// Extra bytes live only while the op runs (o_f / o_b).
        transient: f64,
        /// Bytes released when the op completes.
        free: f64,
    },
    /// A collective over `group`: every member must arrive with an
    /// identical signature before any of them proceeds.
    Collective {
        kind: EventKind,
        label: String,
        secs: f64,
        /// Participating logical device indices, sorted ascending.
        group: Vec<usize>,
        /// Content signature (label, duration, group). Group members
        /// posting different signatures = mismatched collective.
        sig: String,
    },
}

pub(crate) fn coll_sig(label: &str, secs: f64, group: &[usize]) -> String {
    let mut h = StableHasher::new();
    h.write_str(label);
    h.write_f64(secs);
    h.write_usize(group.len());
    for &d in group {
        h.write_usize(d);
    }
    h.hex()
}

/// Per-device programs plus the constant parameter-memory offset.
pub struct ProgramSet {
    pub programs: Vec<Vec<SimOp>>,
    pub param_mem: f64,
}

// ---------------------------------------------------------------------------
// the event loop

/// Execute per-device programs to completion. Deterministic: ready
/// collectives resolve in (start time, leader device) order, and no wall
/// clock or randomness is consulted anywhere.
///
/// Errors:
/// * `mismatched collective: ...` — a rendezvous where group members
///   posted different operations;
/// * `deadlock: ...` — some device waits on a collective that can never
///   complete (a peer finished its program, or no group can assemble).
pub fn run_programs(
    progs: &[Vec<SimOp>],
    mesh_shape: &[usize],
    param_mem: f64,
) -> Result<SimTrace> {
    let n = progs.len();
    ensure!(n > 0, "cannot simulate an empty device set");
    let mut pc = vec![0usize; n];
    let mut clock = vec![0.0f64; n];
    let mut mem = vec![param_mem; n];
    let mut peak = vec![param_mem; n];
    let mut events: Vec<Vec<TraceEvent>> =
        (0..n).map(|_| Vec::new()).collect();
    let mut compute_time = 0.0;
    let mut comm_time = 0.0;
    let mut recompute_time = 0.0;
    let mut exposed_grad_time = 0.0;

    loop {
        // drain local compute on every device
        for d in 0..n {
            while let Some(SimOp::Compute {
                kind,
                label,
                secs,
                alloc,
                transient,
                free,
            }) = progs[d].get(pc[d])
            {
                mem[d] += alloc;
                peak[d] = peak[d].max(mem[d] + transient);
                let t0 = clock[d];
                clock[d] += secs;
                mem[d] -= free;
                events[d].push(TraceEvent {
                    kind: *kind,
                    label: label.clone(),
                    t0,
                    t1: clock[d],
                    mem: mem[d],
                });
                // SPMD totals: count one device's queue, not n copies
                if d == 0 {
                    if *kind == EventKind::Recompute {
                        recompute_time += secs;
                    } else {
                        compute_time += secs;
                    }
                }
                pc[d] += 1;
            }
        }
        if (0..n).all(|d| pc[d] >= progs[d].len()) {
            break;
        }

        // rendezvous: find the ready group with the earliest start
        let mut chosen: Option<(Vec<usize>, f64)> = None;
        for d in 0..n {
            let Some(SimOp::Collective { label, group, sig, .. }) =
                progs[d].get(pc[d])
            else {
                continue;
            };
            ensure!(
                group.contains(&d),
                "collective '{label}' posted by device {d} excludes \
                 itself from group {group:?}"
            );
            if group[0] != d {
                continue; // each group is evaluated once, at its leader
            }
            let mut ready = true;
            for &m in group.iter() {
                match progs[m].get(pc[m]) {
                    Some(SimOp::Collective {
                        label: l2,
                        group: g2,
                        sig: s2,
                        ..
                    }) => {
                        if g2 != group {
                            ready = false; // parked on another collective
                            break;
                        }
                        if s2 != sig {
                            bail!(
                                "mismatched collective: device {d} posts \
                                 '{label}' but device {m} posts '{l2}' \
                                 over group {group:?}"
                            );
                        }
                    }
                    Some(_) => {
                        ready = false;
                        break;
                    }
                    None => bail!(
                        "deadlock: device {m} finished its program while \
                         device {d} waits on '{label}' over group \
                         {group:?}"
                    ),
                }
            }
            if ready {
                let start = group
                    .iter()
                    .map(|&m| clock[m])
                    .fold(f64::NEG_INFINITY, f64::max);
                let better = match &chosen {
                    None => true,
                    Some((g, s)) => {
                        start < *s || (start == *s && group[0] < g[0])
                    }
                };
                if better {
                    chosen = Some((group.clone(), start));
                }
            }
        }
        let Some((group, start)) = chosen else {
            let waiting: Vec<String> = (0..n)
                .filter_map(|d| match progs[d].get(pc[d]) {
                    Some(SimOp::Collective { label, group, .. }) => {
                        Some(format!("dev {d}: '{label}' {group:?}"))
                    }
                    _ => None,
                })
                .collect();
            bail!(
                "deadlock: no collective can assemble its group \
                 [{}]",
                waiting.join("; ")
            );
        };
        let leader = group[0];
        let (kind, label, secs) = match &progs[leader][pc[leader]] {
            SimOp::Collective { kind, label, secs, .. } => {
                (*kind, label.clone(), *secs)
            }
            _ => unreachable!("leader is parked on a collective"),
        };
        let end = start + secs;
        for &m in &group {
            events[m].push(TraceEvent {
                kind,
                label: label.clone(),
                t0: start,
                t1: end,
                mem: mem[m],
            });
            clock[m] = end;
            pc[m] += 1;
        }
        if group.contains(&0) {
            if kind == EventKind::GradSync {
                exposed_grad_time += secs;
            } else {
                comm_time += secs;
            }
        }
    }

    let step_time = clock.iter().copied().fold(0.0, f64::max);
    let peak_mem = peak.iter().copied().fold(0.0, f64::max);
    Ok(SimTrace {
        mesh_shape: mesh_shape.to_vec(),
        analytic: false,
        step_time,
        peak_mem,
        param_mem,
        compute_time,
        comm_time,
        recompute_time,
        exposed_grad_time,
        devices: (0..n)
            .map(|d| DeviceTimeline {
                device: d,
                peak_mem: peak[d],
                events: std::mem::take(&mut events[d]),
            })
            .collect(),
    })
}

// ---------------------------------------------------------------------------
// schedule emission (shared by replay_exec and simulate_schedule)

/// A resharding collective bound to a forward stage.
struct ReshardOp {
    stage: usize,
    label: String,
    secs: f64,
    /// Mesh axes whose groups rendezvous (empty = whole mesh).
    axes: Vec<usize>,
}

/// Program assembler: identical compute on every device, collectives
/// instantiated per mesh axis group.
struct Builder<'m> {
    mesh: Option<&'m DeviceMesh>,
    progs: Vec<Vec<SimOp>>,
}

impl<'m> Builder<'m> {
    fn new(mesh: Option<&'m DeviceMesh>) -> Builder<'m> {
        let n = mesh.map(|m| m.n_devices()).unwrap_or(1).max(1);
        Builder { mesh, progs: (0..n).map(|_| Vec::new()).collect() }
    }

    fn n(&self) -> usize {
        self.progs.len()
    }

    fn compute(
        &mut self,
        kind: EventKind,
        label: &str,
        secs: f64,
        alloc: f64,
        transient: f64,
        free: f64,
    ) {
        for p in self.progs.iter_mut() {
            p.push(SimOp::Compute {
                kind,
                label: label.to_string(),
                secs,
                alloc,
                transient,
                free,
            });
        }
    }

    /// Emit one collective instance per device group along `axes`
    /// (empty axes, or no mesh = one instance over every device).
    fn collective(
        &mut self,
        kind: EventKind,
        label: &str,
        secs: f64,
        axes: &[usize],
    ) {
        let groups = match self.mesh {
            Some(mesh) if !axes.is_empty() => {
                axis_union_groups(mesh, axes)
            }
            _ => vec![(0..self.n()).collect::<Vec<usize>>()],
        };
        for group in groups {
            let sig = coll_sig(label, secs, &group);
            for &d in &group {
                self.progs[d].push(SimOp::Collective {
                    kind,
                    label: label.to_string(),
                    secs,
                    group: group.clone(),
                    sig: sig.clone(),
                });
            }
        }
    }
}

/// Logical device groups that vary along the union of `axes` with every
/// other coordinate fixed (the participant sets of a multi-axis
/// collective). Groups partition `0..n` and are sorted ascending.
fn axis_union_groups(mesh: &DeviceMesh, axes: &[usize]) -> Vec<Vec<usize>> {
    let shape = &mesh.shape;
    let n = mesh.n_devices();
    let mut strides = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    let mut map: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for idx in 0..n {
        let mut key = 0usize;
        for ax in 0..shape.len() {
            if axes.contains(&ax) {
                continue;
            }
            key = key * shape[ax] + (idx / strides[ax]) % shape[ax];
        }
        map.entry(key).or_default().push(idx);
    }
    map.into_values().collect()
}

/// Emit the forward + backward schedule of a stage chain under a
/// checkpoint segmentation. The memory ledger mirrors the rotor DP's
/// accounting: kept stages retain their saved set `ω_ā`; checkpointed
/// blocks retain only their entry boundary and re-execute forward once
/// during backward, re-retaining as they go.
fn emit_schedule(
    b: &mut Builder<'_>,
    stages: &[Stage],
    blocks: &[Block],
    reshard: &[ReshardOp],
) {
    let ln = stages.len();
    let wa_in =
        |s: usize| if s == 0 { 0.0 } else { stages[s - 1].wa_out };
    let wd = stages.last().map(|s| s.wa_out).unwrap_or(0.0);

    // -- forward sweep ----------------------------------------------------
    for blk in blocks {
        for s in blk.start..=blk.end {
            let st = &stages[s];
            if blk.checkpointed {
                let (alloc, transient) = if s == blk.start {
                    // the block's entry boundary stays resident for the
                    // recompute; internals are transient
                    (wa_in(s), st.wa_out + st.of)
                } else {
                    (0.0, wa_in(s) + st.wa_out + st.of)
                };
                b.compute(
                    EventKind::FwdCompute,
                    &format!("fwd s{s} (ckpt)"),
                    st.uf,
                    alloc,
                    transient,
                    0.0,
                );
            } else {
                b.compute(
                    EventKind::FwdCompute,
                    &format!("fwd s{s}"),
                    st.uf,
                    st.wbar,
                    st.of,
                    0.0,
                );
            }
            if st.uf_comm > 0.0 {
                b.collective(
                    EventKind::Comm,
                    &format!("corr fwd s{s}"),
                    st.uf_comm,
                    &[],
                );
            }
            for r in reshard.iter().filter(|r| r.stage == s) {
                b.collective(EventKind::Comm, &r.label, r.secs, &r.axes);
            }
        }
    }
    if ln == 0 {
        // no differentiable stages: only the plan's resharding traffic
        for r in reshard {
            b.collective(EventKind::Comm, &r.label, r.secs, &r.axes);
        }
        return;
    }

    // the loss gradient δ occupies the last boundary's footprint for the
    // whole backward sweep (the DP's ω_δ term)
    if wd > 0.0 {
        b.compute(EventKind::BwdCompute, "loss-grad", 0.0, wd, 0.0, 0.0);
    }

    // -- backward sweep ---------------------------------------------------
    for blk in blocks.iter().rev() {
        if blk.checkpointed {
            for s in blk.start..=blk.end {
                let st = &stages[s];
                b.compute(
                    EventKind::Recompute,
                    &format!("recompute s{s}"),
                    st.uf,
                    st.wbar,
                    st.of,
                    0.0,
                );
                if st.uf_comm > 0.0 {
                    b.collective(
                        EventKind::Comm,
                        &format!("corr fwd s{s} (re)"),
                        st.uf_comm,
                        &[],
                    );
                }
            }
        }
        for s in (blk.start..=blk.end).rev() {
            let st = &stages[s];
            let mut free = st.wbar;
            if blk.checkpointed && s == blk.start {
                free += wa_in(blk.start); // release the entry boundary
            }
            b.compute(
                EventKind::BwdCompute,
                &format!("bwd s{s}"),
                st.ub,
                0.0,
                st.ob,
                free,
            );
            if st.ub_comm > 0.0 {
                b.collective(
                    EventKind::Comm,
                    &format!("corr bwd s{s}"),
                    st.ub_comm,
                    &[],
                );
            }
        }
    }
    if wd > 0.0 {
        b.compute(EventKind::BwdCompute, "step-end", 0.0, 0.0, 0.0, wd);
    }
}

/// Replay a rotor stage chain on one simulated device. `rotor = None`
/// keeps every stage (no checkpointing). This is the mid-level oracle the
/// property tests run against [`RotorSolver`](crate::ckpt::RotorSolver)'s
/// predictions.
pub fn simulate_schedule(
    stages: &[Stage],
    rotor: Option<&RotorSolution>,
    param_mem: f64,
) -> Result<SimTrace> {
    let ln = stages.len();
    let blocks: Vec<Block> = match rotor {
        Some(r) => {
            ensure!(
                r.partitions(ln),
                "invalid checkpoint schedule: blocks do not partition \
                 {ln} stages"
            );
            r.blocks.clone()
        }
        None if ln == 0 => Vec::new(),
        None => vec![Block { start: 0, end: ln - 1, checkpointed: false }],
    };
    let mut b = Builder::new(None);
    emit_schedule(&mut b, stages, &blocks, &[]);
    run_programs(&b.progs, &[1], param_mem)
}

// ---------------------------------------------------------------------------
// full-plan replay

/// Artifact-level structural validation, independent of the graph: node
/// references in range, sharding specs confined to the mesh, collective
/// times finite, checkpoint blocks contiguous. This is what `automap
/// verify` runs before binding a model, so corrupt artifacts fail loudly
/// with a diagnosis instead of replaying garbage.
pub fn validate_exec(
    graph_nodes: usize,
    mesh: &DeviceMesh,
    ep: &ExecutionPlan,
) -> Result<()> {
    let prod: usize = mesh.shape.iter().product();
    ensure!(
        prod == mesh.devices.len() && prod > 0,
        "corrupt plan: mesh shape {:?} does not cover its {} device(s)",
        mesh.shape,
        mesh.devices.len()
    );
    ensure!(
        ep.mesh_shape == mesh.shape,
        "corrupt plan: execution plan was lowered for mesh {:?} but the \
         artifact's mesh is {:?}",
        ep.mesh_shape,
        mesh.shape
    );
    for (id, d) in &ep.decisions {
        ensure!(
            *id < graph_nodes && d.node == *id,
            "corrupt plan: decision for node {id} outside the \
             {graph_nodes}-node graph"
        );
        for ax in d.out_spec.used_axes() {
            ensure!(
                ax < mesh.n_axes(),
                "corrupt plan: decision for node {id} shards on mesh \
                 axis {ax} of a {}-axis mesh",
                mesh.n_axes()
            );
        }
        for x in [d.compute_time, d.comm_time, d.grad_comm, d.mem_bytes] {
            ensure!(
                x.is_finite() && x >= 0.0,
                "corrupt plan: non-finite or negative cost on node {id}"
            );
        }
    }
    for c in &ep.comms {
        ensure!(
            c.time.is_finite() && c.time >= 0.0,
            "corrupt plan: collective after node {} has a non-finite or \
             negative duration",
            c.after
        );
        ensure!(
            ep.decisions.contains_key(&c.after),
            "mismatched collective: comm after node {} has no matching \
             strategy decision",
            c.after
        );
        if let Some(t) = c.for_consumer {
            ensure!(
                ep.decisions.contains_key(&t),
                "mismatched collective: comm after node {} targets \
                 consumer node {t} which has no strategy decision",
                c.after
            );
        }
    }
    if let Some(r) = &ep.ckpt {
        let mut next = 0usize;
        for blk in &r.blocks {
            ensure!(
                blk.start == next && blk.end >= blk.start,
                "invalid checkpoint schedule: block [{}, {}] breaks the \
                 stage partition at {next}",
                blk.start,
                blk.end
            );
            next = blk.end + 1;
        }
    }
    Ok(())
}

/// Rebuild the per-node times the checkpoint stage derived from the
/// sharding solution — replay must price stages exactly as the planner
/// did, or the oracle would compare apples to oranges.
pub(crate) fn times_from_plan(
    g: &Graph,
    ep: &ExecutionPlan,
    mesh: &DeviceMesh,
) -> NodeTimes {
    let mut t = NodeTimes::zeroed(g.len());
    for (id, d) in &ep.decisions {
        t.set_split(
            *id,
            d.compute_time,
            d.comm_time,
            d.out_spec.sharding_factor(mesh) as f64,
        );
    }
    t
}

/// Build the full per-device program set for a lowered plan.
pub fn build_programs(
    g: &Graph,
    mesh: &DeviceMesh,
    ep: &ExecutionPlan,
    dev: &DeviceModel,
) -> Result<ProgramSet> {
    validate_exec(g.len(), mesh, ep)?;
    let groups = linearize(g, &common_nodes(g));
    let times = times_from_plan(g, ep, mesh);
    let stages = build_stages(g, &groups, dev, Some(&times));
    let ln = stages.len();
    let blocks: Vec<Block> = match &ep.ckpt {
        Some(r) => {
            ensure!(
                r.partitions(ln),
                "invalid checkpoint schedule: blocks do not partition \
                 the {ln}-stage linearization of '{}'",
                g.name
            );
            r.blocks.clone()
        }
        None if ln == 0 => Vec::new(),
        None => vec![Block { start: 0, end: ln - 1, checkpointed: false }],
    };

    let mut stage_of = vec![usize::MAX; g.len()];
    for (si, grp) in groups.iter().enumerate() {
        for &id in grp {
            stage_of[id] = si;
        }
    }
    let mut reshard: Vec<ReshardOp> = Vec::new();
    for c in &ep.comms {
        if c.reason != CommReason::Resharding {
            continue; // correctness comm is priced inside the stages;
                      // grad sync is the overlapped aggregate below
        }
        let stage = if stage_of[c.after] != usize::MAX {
            stage_of[c.after]
        } else {
            c.for_consumer
                .map(|t| stage_of[t])
                .filter(|&s| s != usize::MAX)
                .unwrap_or(0)
        };
        reshard.push(ReshardOp {
            stage: stage.min(ln.saturating_sub(1)),
            label: match c.for_consumer {
                Some(t) => format!("reshard n{} -> n{t}", c.after),
                None => format!("reshard n{}", c.after),
            },
            secs: c.time,
            axes: comm_axes(ep, c),
        });
    }

    // gradient sync: overlapped with backward compute; only the exposed
    // remainder serializes (the planner's exact formula)
    let grad_total: f64 =
        ep.decisions.values().map(|d| d.grad_comm).sum();
    let bwd_compute: f64 = ep
        .decisions
        .values()
        .map(|d| crate::ckpt::bwd_share(d.compute_time))
        .sum();
    let exposed = exposed_grad(grad_total, bwd_compute);

    let param_mem: f64 = ep
        .decisions
        .iter()
        .filter(|(id, _)| matches!(g.node(**id).op, Op::Placeholder(_)))
        .map(|(_, d)| d.mem_bytes)
        .sum();

    let mut b = Builder::new(Some(mesh));
    emit_schedule(&mut b, &stages, &blocks, &reshard);
    if exposed > 0.0 {
        b.collective(
            EventKind::GradSync,
            "grad-sync (exposed)",
            exposed,
            &[],
        );
    }
    Ok(ProgramSet { programs: b.progs, param_mem })
}

/// Mesh axes a resharding collective moves data across: the union of the
/// producer's and consumer's sharded axes (empty = whole mesh).
fn comm_axes(ep: &ExecutionPlan, c: &CommInsert) -> Vec<usize> {
    let mut axes: Vec<usize> = Vec::new();
    let mut add = |node: usize| {
        if let Some(d) = ep.decisions.get(&node) {
            for ax in d.out_spec.used_axes() {
                if !axes.contains(&ax) {
                    axes.push(ax);
                }
            }
        }
    };
    add(c.after);
    if let Some(t) = c.for_consumer {
        add(t);
    }
    axes.sort_unstable();
    axes
}

/// Replay a lowered execution plan across its mesh and return the trace.
pub fn replay_exec(
    g: &Graph,
    mesh: &DeviceMesh,
    ep: &ExecutionPlan,
    dev: &DeviceModel,
) -> Result<SimTrace> {
    let ps = build_programs(g, mesh, ep, dev)?;
    run_programs(&ps.programs, &mesh.shape, ps.param_mem)
}

/// Degenerate replay for analytic (closed-form baseline) plans, which
/// carry no per-node schedule: one aggregate step per device echoing the
/// report's time/memory, flagged `analytic` in the trace.
pub fn replay_analytic(
    mesh_shape: &[usize],
    n_devices: usize,
    iter_time: f64,
    mem_per_device: f64,
) -> Result<SimTrace> {
    let n = n_devices.max(1);
    let progs: Vec<Vec<SimOp>> = (0..n)
        .map(|_| {
            vec![SimOp::Compute {
                kind: EventKind::FwdCompute,
                label: "analytic step".into(),
                secs: iter_time,
                alloc: 0.0,
                transient: 0.0,
                free: 0.0,
            }]
        })
        .collect();
    let mut trace = run_programs(&progs, mesh_shape, mem_per_device)?;
    trace.analytic = true;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::RotorSolver;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};
    use crate::graph::Graph;
    use crate::layout::LayoutManager;
    use crate::solver::{solve, SolveOpts, SolverGraph};

    fn coll(label: &str, secs: f64, group: Vec<usize>) -> SimOp {
        let sig = coll_sig(label, secs, &group);
        SimOp::Collective {
            kind: EventKind::Comm,
            label: label.into(),
            secs,
            group,
            sig,
        }
    }

    fn work(secs: f64) -> SimOp {
        SimOp::Compute {
            kind: EventKind::FwdCompute,
            label: "work".into(),
            secs,
            alloc: 0.0,
            transient: 0.0,
            free: 0.0,
        }
    }

    #[test]
    fn rendezvous_waits_for_the_slowest_member() {
        let progs = vec![
            vec![work(1.0), coll("ar", 0.5, vec![0, 1])],
            vec![work(3.0), coll("ar", 0.5, vec![0, 1])],
        ];
        let t = run_programs(&progs, &[2], 0.0).unwrap();
        // device 0 idles until device 1 arrives at t=3, then both spend 0.5
        assert_eq!(t.step_time, 3.5);
        assert_eq!(t.devices[0].events.last().unwrap().t0, 3.0);
    }

    #[test]
    fn disjoint_groups_run_concurrently() {
        let progs = vec![
            vec![coll("a", 2.0, vec![0, 1])],
            vec![coll("a", 2.0, vec![0, 1])],
            vec![coll("b", 1.0, vec![2, 3])],
            vec![coll("b", 1.0, vec![2, 3])],
        ];
        let t = run_programs(&progs, &[4], 0.0).unwrap();
        assert_eq!(t.step_time, 2.0);
        assert_eq!(t.devices[2].events[0].t1, 1.0);
    }

    #[test]
    fn mismatched_signatures_are_detected() {
        let progs = vec![
            vec![coll("all_reduce 4MB", 0.5, vec![0, 1])],
            vec![coll("all_gather 2MB", 0.5, vec![0, 1])],
        ];
        let err =
            run_programs(&progs, &[2], 0.0).unwrap_err().to_string();
        assert!(err.contains("mismatched collective"), "{err}");
    }

    #[test]
    fn finished_peer_is_a_deadlock() {
        let progs = vec![vec![coll("ar", 0.5, vec![0, 1])], vec![]];
        let err =
            run_programs(&progs, &[2], 0.0).unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn crossed_groups_deadlock() {
        // device 0 waits on {0,1}; device 1 waits on {1,2}; device 2 on
        // {0,2}: a rendezvous cycle no group can break
        let progs = vec![
            vec![coll("a", 1.0, vec![0, 1])],
            vec![coll("b", 1.0, vec![1, 2])],
            vec![coll("c", 1.0, vec![0, 2])],
        ];
        let err =
            run_programs(&progs, &[3], 0.0).unwrap_err().to_string();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn ledger_tracks_transients_and_frees() {
        let progs = vec![vec![
            SimOp::Compute {
                kind: EventKind::FwdCompute,
                label: "a".into(),
                secs: 1.0,
                alloc: 100.0,
                transient: 50.0,
                free: 0.0,
            },
            SimOp::Compute {
                kind: EventKind::BwdCompute,
                label: "b".into(),
                secs: 1.0,
                alloc: 0.0,
                transient: 20.0,
                free: 100.0,
            },
        ]];
        let t = run_programs(&progs, &[1], 10.0).unwrap();
        assert_eq!(t.peak_mem, 160.0); // params 10 + alloc 100 + of 50
        assert_eq!(t.devices[0].events[1].mem, 10.0); // back to params
        assert_eq!(t.param_mem, 10.0);
    }

    fn stages_for(g: &Graph) -> Vec<Stage> {
        let groups = linearize(g, &common_nodes(g));
        build_stages(g, &groups, &DeviceModel::a100_80gb(), None)
    }

    #[test]
    fn unconstrained_schedule_matches_no_checkpoint_exactly() {
        let g = gpt2(&Gpt2Cfg::mini());
        let stages = stages_for(&g);
        let r = RotorSolver::new(stages.clone());
        let sol = r.solve(r.no_checkpoint_mem() * 4.0).unwrap();
        let t = simulate_schedule(&stages, Some(&sol), 0.0).unwrap();
        let rel = (t.step_time - r.no_checkpoint_time()).abs()
            / r.no_checkpoint_time();
        assert!(rel < 1e-9, "sim {} vs dp {}", t.step_time, sol.time);
        assert_eq!(t.recompute_time, 0.0);
        // peak stays under the rotor's conservative no-checkpoint bound
        assert!(t.peak_mem <= r.no_checkpoint_mem() * (1.0 + 1e-9));
        assert!(t.peak_mem > 0.0);
    }

    #[test]
    fn tight_schedule_recomputes_but_never_beats_the_dp() {
        let g = gpt2(&Gpt2Cfg::mini());
        let stages = stages_for(&g);
        let r = RotorSolver::new(stages.clone());
        let budget = r.no_checkpoint_mem() * 0.45;
        let sol = r.solve(budget).unwrap();
        let t = simulate_schedule(&stages, Some(&sol), 0.0).unwrap();
        assert!(t.recompute_time > 0.0, "tight budget must recompute");
        // flattened recompute-once replay is bounded by the DP's time
        // (the DP may nest further recomputation)
        assert!(
            t.step_time <= sol.time * (1.0 + 1e-9),
            "sim {} exceeds dp {}",
            t.step_time,
            sol.time
        );
        assert!(
            t.step_time > r.no_checkpoint_time() * (1.0 + 1e-9),
            "recompute must cost time"
        );
    }

    fn lowered_plan(
        g: &Graph,
        mesh: &DeviceMesh,
    ) -> crate::gen::ExecutionPlan {
        let lm = LayoutManager::new(mesh.clone());
        let sg =
            SolverGraph::build(g, mesh, &DeviceModel::a100_80gb(), &lm);
        let sol = solve(
            &sg,
            1e13,
            SolveOpts { anneal_iters: 200, ..Default::default() },
        )
        .unwrap();
        crate::gen::lower(g, &sg, &sol, mesh, &lm, None)
    }

    fn mesh4() -> DeviceMesh {
        DeviceMesh {
            shape: vec![4],
            devices: (0..4).collect(),
            axis_alpha: vec![1e-6],
            axis_beta: vec![1e11],
        }
    }

    #[test]
    fn replay_of_a_lowered_plan_is_deterministic() {
        let g = mlp(64, &[256, 128, 10]);
        let mesh = mesh4();
        let ep = lowered_plan(&g, &mesh);
        let dev = DeviceModel::a100_80gb();
        let a = replay_exec(&g, &mesh, &ep, &dev).unwrap();
        let b = replay_exec(&g, &mesh, &ep, &dev).unwrap();
        assert!(a.step_time > 0.0 && a.step_time.is_finite());
        assert!(a.peak_mem >= a.param_mem);
        assert_eq!(
            a.to_json_value().to_string(),
            b.to_json_value().to_string(),
            "replay must be bit-deterministic"
        );
        // every device ran the same SPMD schedule
        for d in &a.devices {
            assert_eq!(d.events.len(), a.devices[0].events.len());
        }
    }

    #[test]
    fn replay_rejects_corrupt_plans() {
        let g = mlp(64, &[256, 128, 10]);
        let mesh = mesh4();
        let dev = DeviceModel::a100_80gb();

        // a comm pointing at a node with no decision
        let mut ep = lowered_plan(&g, &mesh);
        ep.comms.push(crate::gen::CommInsert {
            after: g.len() + 7,
            for_consumer: None,
            reason: CommReason::Resharding,
            describe: "bogus".into(),
            time: 1e-3,
        });
        let err = replay_exec(&g, &mesh, &ep, &dev)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mismatched collective"), "{err}");

        // a checkpoint segmentation that skips a stage
        let mut ep = lowered_plan(&g, &mesh);
        ep.ckpt = Some(RotorSolution {
            time: 1.0,
            budget: 1.0,
            blocks: vec![Block { start: 1, end: 2, checkpointed: true }],
        });
        let err = replay_exec(&g, &mesh, &ep, &dev)
            .unwrap_err()
            .to_string();
        assert!(err.contains("checkpoint schedule"), "{err}");

        // a decision sharding on a mesh axis that does not exist
        let mut ep = lowered_plan(&g, &mesh);
        let id = *ep.decisions.keys().next().unwrap();
        ep.decisions.get_mut(&id).unwrap().out_spec =
            crate::spec::ShardingSpec::new(&[&[5], &[]]);
        let err = replay_exec(&g, &mesh, &ep, &dev)
            .unwrap_err()
            .to_string();
        assert!(err.contains("mesh axis 5"), "{err}");
    }

    #[test]
    fn axis_union_groups_partition_the_mesh() {
        let mesh = DeviceMesh {
            shape: vec![2, 4],
            devices: (0..8).collect(),
            axis_alpha: vec![1e-6; 2],
            axis_beta: vec![1e11; 2],
        };
        for axes in [vec![0], vec![1], vec![0, 1]] {
            let groups = axis_union_groups(&mesh, &axes);
            let mut all: Vec<usize> = groups.concat();
            all.sort_unstable();
            assert_eq!(all, (0..8).collect::<Vec<_>>());
            let per: usize = axes.iter().map(|&a| mesh.shape[a]).product();
            for grp in &groups {
                assert_eq!(grp.len(), per);
            }
        }
    }

    #[test]
    fn analytic_replay_echoes_the_report() {
        let t = replay_analytic(&[8], 8, 0.25, 3e10).unwrap();
        assert!(t.analytic);
        assert_eq!(t.step_time, 0.25);
        assert_eq!(t.peak_mem, 3e10);
        assert_eq!(t.devices.len(), 8);
    }
}
