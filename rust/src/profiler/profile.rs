//! Whole-graph symbolic profiler (§4.1): a single liveness-aware topo scan
//! over metas produces per-node costs, FLOP totals, and the peak-memory
//! estimate that Fig. 4 compares against real execution.

use crate::graph::op::{Op, PlaceholderKind};
use crate::graph::{Graph, NodeId};

use super::cost::{node_cost, NodeCost};

#[derive(Debug, Clone)]
pub struct GraphProfile {
    pub costs: Vec<NodeCost>,
    /// Parameter bytes (model data: params; grads/optimizer are multiples).
    pub model_bytes: usize,
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    /// Peak of live activation bytes during the forward pass (liveness scan).
    pub peak_fwd_activation: usize,
    /// Node at which the forward peak occurs.
    pub peak_node: NodeId,
    /// Total bytes stashed for backward (what activation checkpointing
    /// trades against recompute).
    pub saved_activation: usize,
    /// Estimated peak during a full training step:
    /// params + grads + saved activations + the worst transient.
    pub peak_training: usize,
}

impl GraphProfile {
    pub fn total_flops(&self) -> f64 {
        self.fwd_flops + self.bwd_flops
    }
}

/// Symbolically profile `g`. Cost: one pass over nodes — the "trivial time"
/// claim of the paper holds by construction (no tensor data is touched).
pub fn profile(g: &Graph) -> GraphProfile {
    let costs: Vec<NodeCost> =
        (0..g.len()).map(|id| node_cost(g, id)).collect();
    let users = g.users();

    // liveness scan over the forward pass -------------------------------
    // In-place ops alias their producer's storage: the alias *root* owns
    // the bytes and stays alive until every user of every alias has run.
    let is_in_place = |id: NodeId| {
        matches!(
            g.node(id).op,
            Op::EwUnary { in_place: true, .. }
                | Op::EwBinary { in_place: true, .. }
        )
    };
    let mut alias_root: Vec<NodeId> = (0..g.len()).collect();
    for n in &g.nodes {
        if is_in_place(n.id) {
            alias_root[n.id] = alias_root[n.inputs[0]];
        }
    }
    // remaining[root] = #unexecuted consumers across all aliases of root
    let mut remaining = vec![0usize; g.len()];
    for (id, us) in users.iter().enumerate() {
        remaining[alias_root[id]] += us.len();
    }

    let mut live: usize = 0;
    let mut peak: usize = 0;
    let mut peak_node: NodeId = 0;
    let mut alive = vec![false; g.len()];

    for n in &g.nodes {
        match n.op {
            // params/consts live in model data, not activations
            Op::Placeholder(PlaceholderKind::Param)
            | Op::Placeholder(PlaceholderKind::Const) => continue,
            Op::Output => continue,
            _ => {}
        }
        let c = &costs[n.id];
        let aliased = alias_root[n.id] != n.id;
        let out_bytes = if aliased { 0 } else { n.out.bytes() };
        live += out_bytes + c.fwd_tmp;
        alive[n.id] = !aliased;
        if live > peak {
            peak = live;
            peak_node = n.id;
        }
        live -= c.fwd_tmp;
        // this node has now consumed its inputs: release dead roots
        for &i in &n.inputs {
            let r = alias_root[i];
            remaining[r] -= 1;
            if remaining[r] == 0 && alive[r] {
                live -= g.node(r).out.bytes();
                alive[r] = false;
            }
        }
    }

    let model_bytes = g.param_bytes();
    let fwd_flops: f64 = costs.iter().map(|c| c.fwd_flops).sum();
    let bwd_flops: f64 = costs.iter().map(|c| c.bwd_flops).sum();
    let saved_activation: usize = costs.iter().map(|c| c.fwd_in).sum();
    let worst_transient = costs
        .iter()
        .map(|c| c.bwd_tmp + c.fwd_tmp)
        .max()
        .unwrap_or(0);
    // grads mirror params; SGD keeps no extra state.
    let peak_training =
        2 * model_bytes + saved_activation + worst_transient;

    GraphProfile {
        costs,
        model_bytes,
        fwd_flops,
        bwd_flops,
        peak_fwd_activation: peak,
        peak_node,
        saved_activation,
        peak_training,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};
    use crate::graph::GraphBuilder;

    #[test]
    fn chain_peak_is_not_sum() {
        // x -> m1 -> relu -> m2: peak must be far below the sum of all outs
        let g = mlp(32, &[256, 256, 256, 256, 10]);
        let p = profile(&g);
        let total_out: usize = p.costs.iter().map(|c| c.fwd_out).sum();
        assert!(p.peak_fwd_activation < total_out);
        assert!(p.peak_fwd_activation > 0);
    }

    #[test]
    fn gpt2_mini_profile_is_sane() {
        let cfg = Gpt2Cfg::mini();
        let g = gpt2(&cfg);
        let p = profile(&g);
        assert_eq!(p.model_bytes, cfg.n_params() * 4);
        // 6 * N * tokens is the standard fwd+bwd FLOP rule of thumb;
        // ours counts per-op so it should be within 2x of it.
        let rule = 6.0 * cfg.n_params() as f64
            * (cfg.batch * cfg.seq) as f64;
        assert!(
            p.total_flops() > rule * 0.5 && p.total_flops() < rule * 4.0,
            "flops {:.2e} vs rule {rule:.2e}",
            p.total_flops()
        );
        assert!(p.peak_training > p.model_bytes * 2);
    }

    #[test]
    fn profiling_is_fast_even_for_paper_scale() {
        // the whole point of symbolic profiling: delta (14.5B params) in ms
        let t0 = std::time::Instant::now();
        let g = gpt2(&Gpt2Cfg::paper("delta"));
        let p = profile(&g);
        assert!(p.model_bytes > 50_000_000_000); // >50 GB of params
        assert!(
            t0.elapsed().as_millis() < 2000,
            "symbolic profile took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn inplace_relu_adds_no_activation() {
        // the relu moment is the peak: copy mode holds h + relu(h) at once
        let build = |in_place: bool| {
            let mut b = GraphBuilder::new("t");
            let x = b.input("x", vec![64, 64]);
            let w = b.param("w", vec![64, 256]);
            let h = b.matmul("h", x, w);
            let r = if in_place {
                b.ew_unary_inplace("r", crate::graph::EwUnary::Relu, h)
            } else {
                b.ew_unary("r", crate::graph::EwUnary::Relu, h)
            };
            let w2 = b.param("w2", vec![256, 4]);
            let y = b.matmul("y", r, w2);
            b.output(&[y]);
            profile(&b.finish().unwrap())
        };
        let p_inplace = build(true);
        let p_copy = build(false);
        assert!(
            p_inplace.peak_fwd_activation < p_copy.peak_fwd_activation
        );
    }
}
