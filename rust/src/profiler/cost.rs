//! Per-node cost model: the paper's five-bucket memory decomposition
//! (fwd_in / fwd_tmp / fwd_out / bwd_tmp / bwd_out, §4.1 Fig. 3) plus
//! forward/backward FLOPs — all derived symbolically from op + metas.

use crate::graph::infer::{bwd_flops, fwd_flops};
use crate::graph::meta::TensorMeta;
use crate::graph::op::{EwUnary, Op};
use crate::graph::{Graph, NodeId};

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NodeCost {
    pub fwd_flops: f64,
    pub bwd_flops: f64,
    /// Bytes of input tensors *saved for backward* by this op.
    pub fwd_in: usize,
    /// Transient bytes alive only during the forward kernel.
    pub fwd_tmp: usize,
    /// Bytes of this op's forward output.
    pub fwd_out: usize,
    /// Transient bytes alive only during the backward kernel.
    pub bwd_tmp: usize,
    /// Bytes of gradients this op emits (≈ fwd_in, as the paper notes).
    pub bwd_out: usize,
}

impl NodeCost {
    pub fn total_flops(&self) -> f64 {
        self.fwd_flops + self.bwd_flops
    }

    /// Activation bytes that persist from forward until this op's backward
    /// has run (what checkpointing can reclaim).
    pub fn saved_bytes(&self) -> usize {
        self.fwd_in
    }
}

/// Which inputs an op must stash for its backward pass.
///
/// Mirrors torch autograd's saved-tensor behaviour for the op classes we
/// model; `in_place` consumers instead borrow their producer's storage,
/// which `profile::GraphProfile` accounts for at the graph level.
fn saved_input_bytes(op: &Op, ins: &[&TensorMeta]) -> usize {
    let b = |i: usize| ins[i].bytes();
    match op {
        // GEMM-family saves both operands (dX needs W, dW needs X).
        Op::Matmul | Op::BatchMatmul | Op::Conv2d { .. } => b(0) + b(1),
        // gather: only ids (int, small) are needed
        Op::Embedding => b(1),
        // normalizations save x (+ per-row stats, counted in bwd_tmp)
        Op::LayerNorm | Op::BatchNorm => b(0),
        // softmax / tanh / gelu save their *output* (same bytes as input)
        Op::Softmax { .. } => b(0),
        Op::EwUnary { kind, .. } => match kind {
            EwUnary::Relu => b(0) / 4, // bool mask is enough (byte/elem)
            EwUnary::Neg | EwUnary::Cast => 0,
            _ => b(0),
        },
        // add/sub need nothing; mul/div/where save operands
        Op::EwBinary { kind, .. } => match kind {
            crate::graph::op::EwBinary::Add
            | crate::graph::op::EwBinary::Sub => 0,
            crate::graph::op::EwBinary::Where => b(1), // mask only
            _ => b(0) + b(1),
        },
        Op::Reduce { .. } | Op::Pool2d { .. } => 0,
        Op::CrossEntropy => b(0) + b(1), // logits + targets
        Op::Reshape { .. }
        | Op::Transpose { .. }
        | Op::Slice { .. }
        | Op::Concat { .. }
        | Op::Placeholder(_)
        | Op::Output => 0,
    }
}

fn fwd_tmp_bytes(op: &Op, ins: &[&TensorMeta], out: &TensorMeta) -> usize {
    match op {
        // row statistics (mean, rstd) in f32
        Op::LayerNorm => {
            let rows = ins[0].numel() / ins[0].shape.last().unwrap();
            2 * rows * 4
        }
        Op::BatchNorm => 2 * ins[0].shape[1] * 4,
        // softmax runs in-place on its output buffer (matches both the
        // instrumented interpreter and torch's eager kernel)
        Op::Softmax { .. } => 0,
        Op::CrossEntropy => ins[0].bytes(), // log-softmax buffer
        _ => 0,
    }
}

fn bwd_tmp_bytes(op: &Op, ins: &[&TensorMeta], out: &TensorMeta) -> usize {
    match op {
        // dSoftmax materializes p * dy
        Op::Softmax { .. } => out.bytes(),
        Op::LayerNorm => ins[0].bytes(), // xhat recompute buffer
        Op::CrossEntropy => ins[0].bytes(),
        _ => 0,
    }
}

fn grad_out_bytes(op: &Op, ins: &[&TensorMeta]) -> usize {
    match op {
        Op::Placeholder(_) | Op::Output => 0,
        // grads flow to every differentiable input
        _ => ins
            .iter()
            .filter(|t| t.dtype.differentiable())
            .map(|t| t.bytes())
            .sum(),
    }
}

/// Symbolically profile one node (meta-execution: no storage touched).
pub fn node_cost(g: &Graph, id: NodeId) -> NodeCost {
    let n = g.node(id);
    let ins: Vec<&TensorMeta> =
        n.inputs.iter().map(|&i| &g.node(i).out).collect();
    let out = &n.out;
    match n.op {
        Op::Placeholder(_) | Op::Output => NodeCost::default(),
        _ => NodeCost {
            fwd_flops: fwd_flops(&n.op, &ins, out),
            bwd_flops: bwd_flops(&n.op, &ins, out),
            fwd_in: saved_input_bytes(&n.op, &ins),
            fwd_tmp: fwd_tmp_bytes(&n.op, &ins, out),
            fwd_out: out.bytes(),
            bwd_tmp: bwd_tmp_bytes(&n.op, &ins, out),
            bwd_out: grad_out_bytes(&n.op, &ins),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    #[test]
    fn matmul_saves_both_operands() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![4, 8]);
        let w = b.param("w", vec![8, 2]);
        let y = b.matmul("y", x, w);
        b.output(&[y]);
        let g = b.finish().unwrap();
        let c = node_cost(&g, y);
        assert_eq!(c.fwd_in, (4 * 8 + 8 * 2) * 4);
        assert_eq!(c.fwd_out, 4 * 2 * 4);
        assert_eq!(c.bwd_out, (4 * 8 + 8 * 2) * 4);
        assert_eq!(c.fwd_flops, 2.0 * 4.0 * 2.0 * 8.0);
        assert_eq!(c.bwd_flops, 2.0 * c.fwd_flops);
    }

    #[test]
    fn add_saves_nothing() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![16, 16]);
        let y = b.input("y", vec![16, 16]);
        let z = b.add_t("z", x, y);
        b.output(&[z]);
        let g = b.finish().unwrap();
        let c = node_cost(&g, z);
        assert_eq!(c.fwd_in, 0);
        assert_eq!(c.bwd_out, 2 * 16 * 16 * 4);
    }

    #[test]
    fn relu_saves_mask_only() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![16, 16]);
        let r = b.ew_unary("r", EwUnary::Relu, x);
        b.output(&[r]);
        let g = b.finish().unwrap();
        let c = node_cost(&g, r);
        assert_eq!(c.fwd_in, 16 * 16); // 1 byte per element
    }

    #[test]
    fn layernorm_has_stat_temporaries() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![8, 64, 128]);
        let gm = b.param("g", vec![128]);
        let bt = b.param("b", vec![128]);
        let y = b.layernorm("ln", x, gm, bt);
        b.output(&[y]);
        let g = b.finish().unwrap();
        let c = node_cost(&g, y);
        assert_eq!(c.fwd_tmp, 2 * 8 * 64 * 4);
        assert_eq!(c.bwd_tmp, 8 * 64 * 128 * 4);
    }

    #[test]
    fn placeholders_cost_nothing() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![4]);
        b.output(&[x]);
        let g = b.finish().unwrap();
        assert_eq!(node_cost(&g, x), NodeCost::default());
    }
}
