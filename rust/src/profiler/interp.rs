//! Instrumented interpreter: *real* execution of a graph on heap buffers.
//!
//! This is the comparator for the symbolic profiler (Figs. 2 and 4): it
//! allocates every tensor for real, executes every op with naive kernels,
//! free buffers when their last user has run, and reports measured peak
//! memory + wall time.  It doubles as a numerics oracle for small graphs.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::meta::{DType, TensorMeta};
use crate::graph::op::{EwBinary, EwUnary, Op, PlaceholderKind, PoolKind,
                       ReduceKind};
use crate::graph::{Graph, NodeId};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Bool(Vec<bool>),
}

impl Buf {
    pub fn bytes(&self) -> usize {
        match self {
            Buf::F32(v) => v.len() * 4,
            Buf::I32(v) => v.len() * 4,
            Buf::Bool(v) => v.len(),
        }
    }

    pub fn f32(&self) -> Result<&[f32]> {
        match self {
            Buf::F32(v) => Ok(v),
            _ => bail!("expected f32 buffer"),
        }
    }

    pub fn i32(&self) -> Result<&[i32]> {
        match self {
            Buf::I32(v) => Ok(v),
            _ => bail!("expected i32 buffer"),
        }
    }
}

#[derive(Debug)]
pub struct ExecResult {
    pub outputs: Vec<Buf>,
    /// Peak of live buffer bytes during execution (the "real" counterpart
    /// of `GraphProfile::peak_fwd_activation`, excluding params/consts).
    pub peak_activation: usize,
    pub elapsed: std::time::Duration,
}

struct Tracker {
    live: usize,
    peak: usize,
}

impl Tracker {
    fn alloc(&mut self, bytes: usize) {
        self.live += bytes;
        self.peak = self.peak.max(self.live);
    }

    fn free(&mut self, bytes: usize) {
        self.live -= bytes.min(self.live);
    }
}

/// Random feeds for every placeholder: params N(0, 0.02), inputs N(0, 1),
/// int inputs uniform in [0, hi), bool consts = causal lower-triangular
/// when square else all-true, f32 consts = 1/sqrt(last dim heuristic).
pub fn random_feeds(g: &Graph, seed: u64, int_hi: i32)
                    -> HashMap<NodeId, Buf> {
    let mut rng = Rng::new(seed);
    let mut feeds = HashMap::new();
    for n in &g.nodes {
        let scale = match n.op {
            Op::Placeholder(PlaceholderKind::Param) => 0.05,
            Op::Placeholder(PlaceholderKind::Input) => 1.0,
            Op::Placeholder(PlaceholderKind::Const) => 1.0,
            _ => continue,
        };
        let buf = match n.out.dtype {
            DType::F32 | DType::F16 | DType::BF16 => {
                if n.op == Op::Placeholder(PlaceholderKind::Const)
                    && n.out.shape.is_empty()
                {
                    Buf::F32(vec![0.125]) // attention scale stand-in
                } else {
                    Buf::F32(
                        (0..n.out.numel())
                            .map(|_| (rng.normal() * scale) as f32)
                            .collect(),
                    )
                }
            }
            DType::I32 | DType::I64 => Buf::I32(
                (0..n.out.numel())
                    .map(|_| (rng.below(int_hi as usize)) as i32)
                    .collect(),
            ),
            DType::Bool => {
                let sh = &n.out.shape;
                if sh.len() == 2 && sh[0] == sh[1] {
                    let s = sh[0];
                    Buf::Bool(
                        (0..s * s).map(|i| i % s <= i / s).collect(),
                    )
                } else {
                    Buf::Bool(vec![true; n.out.numel()])
                }
            }
        };
        feeds.insert(n.id, buf);
    }
    feeds
}

/// Execute the forward graph for real, tracking peak live bytes.
pub fn execute(g: &Graph, mut feeds: HashMap<NodeId, Buf>)
               -> Result<ExecResult> {
    let t0 = std::time::Instant::now();
    let users = g.users();
    let mut remaining: Vec<usize> = users.iter().map(|u| u.len()).collect();
    let mut bufs: Vec<Option<Buf>> = (0..g.len()).map(|_| None).collect();
    let mut tr = Tracker { live: 0, peak: 0 };
    let mut outputs = Vec::new();

    for n in &g.nodes {
        let out: Buf = match &n.op {
            Op::Placeholder(_) => feeds
                .remove(&n.id)
                .ok_or_else(|| anyhow!("missing feed for {}", n.name))?,
            Op::Output => {
                for &i in &n.inputs {
                    if let Some(b) = &bufs[i] {
                        outputs.push(b.clone());
                    }
                }
                continue;
            }
            op => {
                let ins: Vec<&Buf> = n
                    .inputs
                    .iter()
                    .map(|&i| {
                        bufs[i]
                            .as_ref()
                            .ok_or_else(|| anyhow!("input {i} freed early"))
                    })
                    .collect::<Result<_>>()?;
                let metas: Vec<&TensorMeta> =
                    n.inputs.iter().map(|&i| &g.node(i).out).collect();
                eval(op, &ins, &metas, &n.out)?
            }
        };
        // placeholders live in "model data"; only op outputs count as
        // activations (mirrors the symbolic scan)
        let is_act = !matches!(n.op, Op::Placeholder(_));
        if is_act {
            tr.alloc(out.bytes());
        }
        bufs[n.id] = Some(out);
        for &i in &n.inputs {
            remaining[i] -= 1;
            if remaining[i] == 0
                && !matches!(g.node(i).op, Op::Placeholder(_))
            {
                if let Some(b) = bufs[i].take() {
                    tr.free(b.bytes());
                }
            }
        }
    }
    Ok(ExecResult {
        outputs,
        peak_activation: tr.peak,
        elapsed: t0.elapsed(),
    })
}

// ---------------------------------------------------------------------------
// naive kernels
// ---------------------------------------------------------------------------

fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

fn eval(op: &Op, ins: &[&Buf], metas: &[&TensorMeta], out_meta: &TensorMeta)
        -> Result<Buf> {
    match op {
        Op::Matmul => {
            let (x, w) = (ins[0].f32()?, ins[1].f32()?);
            let k = *metas[0].shape.last().unwrap();
            let n = metas[1].shape[1];
            let m = metas[0].numel() / k;
            let mut out = vec![0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    let xv = x[i * k + kk];
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &w[kk * n..(kk + 1) * n];
                    let orow = &mut out[i * n..(i + 1) * n];
                    for j in 0..n {
                        orow[j] += xv * wrow[j];
                    }
                }
            }
            Ok(Buf::F32(out))
        }
        Op::BatchMatmul => {
            let (a, b) = (ins[0].f32()?, ins[1].f32()?);
            let r = metas[0].rank();
            let (m, k) = (metas[0].shape[r - 2], metas[0].shape[r - 1]);
            let n = metas[1].shape[r - 1];
            let batch = metas[0].numel() / (m * k);
            let mut out = vec![0f32; batch * m * n];
            for bi in 0..batch {
                let ab = &a[bi * m * k..];
                let bb = &b[bi * k * n..];
                let ob = &mut out[bi * m * n..(bi + 1) * m * n];
                for i in 0..m {
                    for kk in 0..k {
                        let av = ab[i * k + kk];
                        if av == 0.0 {
                            continue;
                        }
                        for j in 0..n {
                            ob[i * n + j] += av * bb[kk * n + j];
                        }
                    }
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Embedding => {
            let (table, ids) = (ins[0].f32()?, ins[1].i32()?);
            let d = metas[0].shape[1];
            let v = metas[0].shape[0] as i32;
            let mut out = Vec::with_capacity(ids.len() * d);
            for &id in ids {
                let id = id.clamp(0, v - 1) as usize;
                out.extend_from_slice(&table[id * d..(id + 1) * d]);
            }
            Ok(Buf::F32(out))
        }
        Op::EwUnary { kind, .. } => {
            let x = ins[0].f32()?;
            let f: fn(f32) -> f32 = match kind {
                EwUnary::Relu => |v| v.max(0.0),
                EwUnary::Gelu => |v| {
                    let c = (2.0f32 / std::f32::consts::PI).sqrt();
                    0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
                },
                EwUnary::Tanh => |v| v.tanh(),
                EwUnary::Exp => |v| v.exp(),
                EwUnary::Neg => |v| -v,
                EwUnary::Sqrt => |v| v.sqrt(),
                EwUnary::Cast => |v| v,
            };
            Ok(Buf::F32(x.iter().map(|&v| f(v)).collect()))
        }
        Op::EwBinary { kind, .. } => {
            let a = ins[0].f32()?;
            let out_n = out_meta.numel();
            // broadcast index helper for rhs (and lhs if needed)
            let bidx = |meta: &TensorMeta, flat: usize| -> usize {
                let os = strides(&out_meta.shape);
                let r_off = out_meta.rank() - meta.rank();
                let ms = strides(&meta.shape);
                let mut idx = 0;
                for (i, s) in os.iter().enumerate() {
                    let coord = (flat / s) % out_meta.shape[i];
                    if i >= r_off {
                        let mi = i - r_off;
                        let c = if meta.shape[mi] == 1 { 0 } else { coord };
                        idx += c * ms[mi];
                    }
                }
                idx
            };
            if let EwBinary::Where = kind {
                // ins[1] is a bool mask; masked positions get -1e30
                let mask = match ins[1] {
                    Buf::Bool(m) => m,
                    _ => bail!("where wants bool mask"),
                };
                let mut out = vec![0f32; out_n];
                for (i, o) in out.iter_mut().enumerate() {
                    let m = mask[bidx(metas[1], i)];
                    *o = if m { a[bidx(metas[0], i)] } else { -1e30 };
                }
                return Ok(Buf::F32(out));
            }
            let b = ins[1].f32()?;
            let f: fn(f32, f32) -> f32 = match kind {
                EwBinary::Add => |x, y| x + y,
                EwBinary::Sub => |x, y| x - y,
                EwBinary::Mul => |x, y| x * y,
                EwBinary::Div => |x, y| x / y,
                EwBinary::Maximum => |x, y| x.max(y),
                EwBinary::Where => unreachable!(),
            };
            let mut out = vec![0f32; out_n];
            for (i, o) in out.iter_mut().enumerate() {
                *o = f(a[bidx(metas[0], i)], b[bidx(metas[1], i)]);
            }
            Ok(Buf::F32(out))
        }
        Op::LayerNorm => {
            let (x, gm, bt) =
                (ins[0].f32()?, ins[1].f32()?, ins[2].f32()?);
            let d = *metas[0].shape.last().unwrap();
            let rows = x.len() / d;
            let mut out = vec![0f32; x.len()];
            for r in 0..rows {
                let row = &x[r * d..(r + 1) * d];
                let mean = row.iter().sum::<f32>() / d as f32;
                let var = row.iter().map(|v| (v - mean) * (v - mean))
                    .sum::<f32>() / d as f32;
                let rstd = 1.0 / (var + 1e-5).sqrt();
                for j in 0..d {
                    out[r * d + j] = (row[j] - mean) * rstd * gm[j] + bt[j];
                }
            }
            Ok(Buf::F32(out))
        }
        Op::BatchNorm => {
            let (x, gm, bt) =
                (ins[0].f32()?, ins[1].f32()?, ins[2].f32()?);
            let c = metas[0].shape[1];
            let spatial = metas[0].numel() / (metas[0].shape[0] * c);
            let n = metas[0].shape[0];
            let mut out = vec![0f32; x.len()];
            for ci in 0..c {
                let mut sum = 0f32;
                let mut sq = 0f32;
                for ni in 0..n {
                    for s in 0..spatial {
                        let v = x[(ni * c + ci) * spatial + s];
                        sum += v;
                        sq += v * v;
                    }
                }
                let cnt = (n * spatial) as f32;
                let mean = sum / cnt;
                let var = sq / cnt - mean * mean;
                let rstd = 1.0 / (var + 1e-5).sqrt();
                for ni in 0..n {
                    for s in 0..spatial {
                        let i = (ni * c + ci) * spatial + s;
                        out[i] = (x[i] - mean) * rstd * gm[ci] + bt[ci];
                    }
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Softmax { axis } => {
            let x = ins[0].f32()?;
            let shape = &metas[0].shape;
            anyhow::ensure!(
                *axis == shape.len() - 1,
                "interp softmax supports last axis only"
            );
            let d = shape[*axis];
            let mut out = vec![0f32; x.len()];
            for r in 0..x.len() / d {
                let row = &x[r * d..(r + 1) * d];
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0f32;
                for j in 0..d {
                    let e = (row[j] - m).exp();
                    out[r * d + j] = e;
                    sum += e;
                }
                for j in 0..d {
                    out[r * d + j] /= sum;
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Reshape { .. } => Ok(ins[0].clone()),
        Op::Transpose { perm } => {
            let x = ins[0].f32()?;
            let in_shape = &metas[0].shape;
            let in_str = strides(in_shape);
            let out_str = strides(&out_meta.shape);
            let mut out = vec![0f32; x.len()];
            for (flat, o) in out.iter_mut().enumerate() {
                let mut src = 0;
                for (i, s) in out_str.iter().enumerate() {
                    let coord = (flat / s) % out_meta.shape[i];
                    src += coord * in_str[perm[i]];
                }
                *o = x[src];
            }
            Ok(Buf::F32(out))
        }
        Op::Slice { axis, start, len } => {
            let x = ins[0].f32()?;
            let shape = &metas[0].shape;
            let inner: usize = shape[axis + 1..].iter().product();
            let outer: usize = shape[..*axis].iter().product();
            let d = shape[*axis];
            let mut out = Vec::with_capacity(outer * len * inner);
            for o in 0..outer {
                let base = (o * d + start) * inner;
                out.extend_from_slice(&x[base..base + len * inner]);
            }
            Ok(Buf::F32(out))
        }
        Op::Concat { axis } => {
            let shape0 = &metas[0].shape;
            let inner: usize = shape0[axis + 1..].iter().product();
            let outer: usize = shape0[..*axis].iter().product();
            let mut out =
                Vec::with_capacity(out_meta.numel());
            for o in 0..outer {
                for (t, m) in ins.iter().zip(metas) {
                    let d = m.shape[*axis];
                    let x = t.f32()?;
                    out.extend_from_slice(
                        &x[o * d * inner..(o + 1) * d * inner],
                    );
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Reduce { kind, axes, .. } => {
            let x = ins[0].f32()?;
            let shape = &metas[0].shape;
            let in_str = strides(shape);
            let mut out = vec![
                match kind {
                    ReduceKind::Max => f32::NEG_INFINITY,
                    _ => 0f32,
                };
                out_meta.numel()
            ];
            let out_dims: Vec<usize> = (0..shape.len())
                .filter(|i| !axes.contains(i))
                .collect();
            let out_str = strides(&out_meta.shape);
            for (flat, &v) in x.iter().enumerate() {
                let mut oi = 0;
                for (k, &d) in out_dims.iter().enumerate() {
                    let coord = (flat / in_str[d]) % shape[d];
                    if k < out_str.len() {
                        oi += coord * out_str[k];
                    }
                }
                match kind {
                    ReduceKind::Sum | ReduceKind::Mean => out[oi] += v,
                    ReduceKind::Max => out[oi] = out[oi].max(v),
                }
            }
            if let ReduceKind::Mean = kind {
                let cnt: usize =
                    axes.iter().map(|&a| shape[a]).product();
                for o in &mut out {
                    *o /= cnt as f32;
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Conv2d { stride, pad } => {
            let (x, w) = (ins[0].f32()?, ins[1].f32()?);
            let (n, c, h, wd) = (
                metas[0].shape[0],
                metas[0].shape[1],
                metas[0].shape[2],
                metas[0].shape[3],
            );
            let (o, _, kh, kw) = (
                metas[1].shape[0],
                metas[1].shape[1],
                metas[1].shape[2],
                metas[1].shape[3],
            );
            let (ho, wo) = (out_meta.shape[2], out_meta.shape[3]);
            let mut out = vec![0f32; n * o * ho * wo];
            for ni in 0..n {
                for oi in 0..o {
                    for yi in 0..ho {
                        for xi in 0..wo {
                            let mut acc = 0f32;
                            for ci in 0..c {
                                for ky in 0..kh {
                                    let sy = yi * stride + ky;
                                    if sy < *pad || sy - pad >= h {
                                        continue;
                                    }
                                    for kx in 0..kw {
                                        let sx = xi * stride + kx;
                                        if sx < *pad || sx - pad >= wd {
                                            continue;
                                        }
                                        acc += x[((ni * c + ci) * h
                                            + (sy - pad))
                                            * wd
                                            + (sx - pad)]
                                            * w[((oi * c + ci) * kh + ky)
                                                * kw
                                                + kx];
                                    }
                                }
                            }
                            out[((ni * o + oi) * ho + yi) * wo + xi] = acc;
                        }
                    }
                }
            }
            Ok(Buf::F32(out))
        }
        Op::Pool2d { kind, size, stride } => {
            let x = ins[0].f32()?;
            let (n, c, h, wd) = (
                metas[0].shape[0],
                metas[0].shape[1],
                metas[0].shape[2],
                metas[0].shape[3],
            );
            let (ho, wo) = (out_meta.shape[2], out_meta.shape[3]);
            let mut out = vec![0f32; n * c * ho * wo];
            for nc in 0..n * c {
                for yi in 0..ho {
                    for xi in 0..wo {
                        let mut acc = match kind {
                            PoolKind::Max => f32::NEG_INFINITY,
                            PoolKind::Avg => 0f32,
                        };
                        for ky in 0..*size {
                            for kx in 0..*size {
                                let v = x[nc * h * wd
                                    + (yi * stride + ky) * wd
                                    + (xi * stride + kx)];
                                match kind {
                                    PoolKind::Max => acc = acc.max(v),
                                    PoolKind::Avg => acc += v,
                                }
                            }
                        }
                        if let PoolKind::Avg = kind {
                            acc /= (size * size) as f32;
                        }
                        out[nc * ho * wo + yi * wo + xi] = acc;
                    }
                }
            }
            Ok(Buf::F32(out))
        }
        Op::CrossEntropy => {
            let (logits, tgt) = (ins[0].f32()?, ins[1].i32()?);
            let v = *metas[0].shape.last().unwrap();
            let rows = logits.len() / v;
            let mut loss = 0f64;
            for r in 0..rows {
                let row = &logits[r * v..(r + 1) * v];
                let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse: f32 =
                    row.iter().map(|&x| (x - m).exp()).sum::<f32>().ln() + m;
                let t = tgt[r].clamp(0, v as i32 - 1) as usize;
                loss += (lse - row[t]) as f64;
            }
            Ok(Buf::F32(vec![(loss / rows as f64) as f32]))
        }
        Op::Placeholder(_) | Op::Output => unreachable!(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models::{gpt2, mlp, Gpt2Cfg};
    use crate::profiler::profile::profile;

    #[test]
    fn executes_mlp_and_tracks_memory() {
        let g = mlp(8, &[32, 64, 16, 4]);
        let feeds = random_feeds(&g, 0, 4);
        let r = execute(&g, feeds).unwrap();
        assert_eq!(r.outputs.len(), 1);
        let loss = r.outputs[0].f32().unwrap()[0];
        assert!(loss.is_finite() && loss > 0.0);
        assert!(r.peak_activation > 0);
    }

    #[test]
    fn gpt2_mini_executes_with_finite_loss() {
        let mut cfg = Gpt2Cfg::mini();
        cfg.batch = 2;
        cfg.seq = 16;
        let g = gpt2(&cfg);
        let feeds = random_feeds(&g, 1, cfg.vocab as i32);
        let r = execute(&g, feeds).unwrap();
        let loss = r.outputs[0].f32().unwrap()[0];
        // untrained random model on 512 classes: loss near ln(512)=6.24
        assert!((loss - 6.24).abs() < 1.0, "loss {loss}");
    }

    #[test]
    fn symbolic_peak_tracks_real_peak() {
        // Fig. 4's claim: symbolic estimate ≈ real execution
        for g in [
            mlp(16, &[128, 256, 128, 64, 10]),
            gpt2(&Gpt2Cfg {
                vocab: 128,
                seq: 16,
                d_model: 32,
                n_layer: 2,
                n_head: 4,
                d_ff: 128,
                batch: 2,
            }),
        ] {
            let sym = profile(&g).peak_fwd_activation;
            let feeds = random_feeds(&g, 2, 16);
            let real = execute(&g, feeds).unwrap().peak_activation;
            let rel = (sym as f64 - real as f64).abs() / real as f64;
            assert!(
                rel < 0.35,
                "{}: symbolic {sym} vs real {real} ({rel:.2})",
                g.name
            );
        }
    }

    #[test]
    fn small_resnet_runs() {
        let g = resnet_small();
        let feeds = random_feeds(&g, 3, 10);
        let r = execute(&g, feeds).unwrap();
        assert!(r.outputs[0].f32().unwrap()[0].is_finite());
    }

    fn resnet_small() -> crate::graph::Graph {
        // scaled-down resnet: 8x8 images via custom builder path
        let mut b = crate::graph::GraphBuilder::new("resnet_tiny");
        let x = b.input("x", vec![2, 3, 8, 8]);
        let w = b.param("c1.w", vec![4, 3, 3, 3]);
        let mut h = b.conv2d("c1", x, w, 1, 1);
        let g1 = b.param("bn.g", vec![4]);
        let b1 = b.param("bn.b", vec![4]);
        h = b.batchnorm("bn", h, g1, b1);
        h = b.ew_unary_inplace("relu", crate::graph::EwUnary::Relu, h);
        h = b.reduce("gap", h, ReduceKind::Mean, vec![2, 3], false);
        let wfc = b.param("fc.w", vec![4, 10]);
        h = b.matmul("fc", h, wfc);
        let t = b.input_ids("t", vec![2]);
        let loss = b.cross_entropy("loss", h, t);
        b.output(&[loss]);
        b.finish().unwrap()
    }

    #[test]
    fn transpose_kernel_is_correct() {
        let mut b = crate::graph::GraphBuilder::new("t");
        let x = b.input("x", vec![2, 3]);
        let t = b.transpose("t", x, vec![1, 0]);
        b.output(&[t]);
        let g = b.finish().unwrap();
        let mut feeds = HashMap::new();
        feeds.insert(x, Buf::F32(vec![1., 2., 3., 4., 5., 6.]));
        let r = execute(&g, feeds).unwrap();
        assert_eq!(
            r.outputs[0].f32().unwrap(),
            &[1., 4., 2., 5., 3., 6.]
        );
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut b = crate::graph::GraphBuilder::new("s");
        let x = b.input("x", vec![4, 8]);
        let s = b.softmax("sm", x, 1);
        b.output(&[s]);
        let g = b.finish().unwrap();
        let r = execute(&g, random_feeds(&g, 4, 1)).unwrap();
        let o = r.outputs[0].f32().unwrap();
        for row in 0..4 {
            let s: f32 = o[row * 8..(row + 1) * 8].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }
}
