//! Symbolic profiler (§4.1) + real-execution comparator.
//!
//! `cost` gives the per-node five-bucket decomposition, `profile` the
//! whole-graph liveness scan, and `interp` the instrumented interpreter
//! that "really executes" graphs for the Fig. 2 / Fig. 4 comparisons.

pub mod cost;
pub mod interp;
pub mod profile;

pub use cost::{node_cost, NodeCost};
pub use interp::{execute, random_feeds, Buf, ExecResult};
pub use profile::{profile, GraphProfile};
