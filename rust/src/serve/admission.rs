//! Per-tenant admission control for the daemon's plan endpoints.
//!
//! Each tenant (the `x-automap-tenant` header, or the spec's `tenant`
//! field, defaulting to `"default"`) gets a bounded in-flight cap and a
//! bounded wait queue. A request either enters immediately, blocks in
//! the queue until a slot frees (handler threads *are* the queue — the
//! bound caps how many may wait), or is rejected with a structured 429
//! when the queue is full. Admission is fairness across tenants, not
//! dedup: identical fingerprints racing through different tenants still
//! collapse to one solve inside `PlanService` (single-flight).

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Tenant name used when a request names none.
pub const DEFAULT_TENANT: &str = "default";

#[derive(Default)]
struct TenantState {
    inflight: usize,
    queued: usize,
}

struct Shared {
    tenants: Mutex<HashMap<String, TenantState>>,
    cv: Condvar,
    max_inflight: usize,
    max_queued: usize,
}

/// Why a request was turned away.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rejected {
    pub tenant: String,
    pub inflight: usize,
    pub queued: usize,
}

pub struct AdmissionQueue {
    shared: Arc<Shared>,
}

/// An admitted request's slot; freeing it (on drop) wakes one queued
/// waiter of the same tenant.
pub struct Permit {
    shared: Arc<Shared>,
    tenant: String,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut map = self.shared.tenants.lock().unwrap();
        if let Some(st) = map.get_mut(&self.tenant) {
            st.inflight = st.inflight.saturating_sub(1);
            if st.inflight == 0 && st.queued == 0 {
                map.remove(&self.tenant);
            }
        }
        self.shared.cv.notify_all();
    }
}

impl AdmissionQueue {
    /// `max_inflight` concurrent plans and at most `max_queued` waiting
    /// requests, independently per tenant.
    pub fn new(max_inflight: usize, max_queued: usize) -> AdmissionQueue {
        AdmissionQueue {
            shared: Arc::new(Shared {
                tenants: Mutex::new(HashMap::new()),
                cv: Condvar::new(),
                max_inflight: max_inflight.max(1),
                max_queued,
            }),
        }
    }

    /// Enter the tenant's queue, blocking until an in-flight slot frees.
    /// Errors immediately when the queue is already at capacity.
    pub fn enter(&self, tenant: &str) -> Result<Permit, Rejected> {
        let mut map = self.shared.tenants.lock().unwrap();
        {
            let st = map.entry(tenant.to_string()).or_default();
            if st.inflight >= self.shared.max_inflight {
                if st.queued >= self.shared.max_queued {
                    return Err(Rejected {
                        tenant: tenant.to_string(),
                        inflight: st.inflight,
                        queued: st.queued,
                    });
                }
                st.queued += 1;
            } else {
                st.inflight += 1;
                return Ok(self.permit(tenant));
            }
        }
        // queued: wait for a slot, then convert queued -> inflight
        loop {
            map = self.shared.cv.wait(map).unwrap();
            let st = map.entry(tenant.to_string()).or_default();
            if st.inflight < self.shared.max_inflight {
                st.queued = st.queued.saturating_sub(1);
                st.inflight += 1;
                return Ok(self.permit(tenant));
            }
        }
    }

    fn permit(&self, tenant: &str) -> Permit {
        Permit {
            shared: Arc::clone(&self.shared),
            tenant: tenant.to_string(),
        }
    }

    /// (inflight, queued) snapshot for a tenant.
    pub fn snapshot(&self, tenant: &str) -> (usize, usize) {
        let map = self.shared.tenants.lock().unwrap();
        map.get(tenant)
            .map(|st| (st.inflight, st.queued))
            .unwrap_or((0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_cap_then_rejects_past_queue() {
        let q = AdmissionQueue::new(2, 0);
        let a = q.enter("t").unwrap();
        let _b = q.enter("t").unwrap();
        // cap reached, zero queue slots: immediate rejection
        let rej = q.enter("t").unwrap_err();
        assert_eq!(rej.inflight, 2);
        drop(a);
        let _c = q.enter("t").expect("slot freed by drop");
    }

    #[test]
    fn tenants_are_isolated() {
        let q = AdmissionQueue::new(1, 0);
        let _a = q.enter("team-a").unwrap();
        assert!(q.enter("team-a").is_err());
        let _b = q.enter("team-b").expect("other tenant unaffected");
        assert_eq!(q.snapshot("team-a"), (1, 0));
        assert_eq!(q.snapshot("team-b"), (1, 0));
    }

    #[test]
    fn queued_request_blocks_until_release() {
        let q = Arc::new(AdmissionQueue::new(1, 4));
        let first = q.enter("t").unwrap();
        let q2 = Arc::clone(&q);
        let waiter = std::thread::spawn(move || {
            let _p = q2.enter("t").unwrap();
        });
        // the waiter must be parked in the queue, not running
        while q.snapshot("t").1 == 0 {
            std::thread::yield_now();
        }
        drop(first);
        waiter.join().unwrap();
        assert_eq!(q.snapshot("t"), (0, 0));
    }
}
