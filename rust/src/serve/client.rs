//! Blocking client for the daemon — the other half of [`super::wire`].
//!
//! Used by `automap plan --remote <addr>` and the loopback tests. Keeps
//! responses as [`Json`] (plus the raw bytes for registry fetches) so
//! callers can check byte-identity against locally produced artifacts.

use anyhow::{anyhow, Result};

use crate::util::json::{arr, obj, s, write_json, Json};

use super::wire::PlanSpec;

/// The decoded body of a successful `POST /v1/plan` entry.
#[derive(Debug, Clone)]
pub struct RemoteOutcome {
    pub fingerprint: String,
    /// `memory-hit | disk-hit | partial-resume | solved` — as reported
    /// by the *server's* cache, not this client.
    pub source: String,
    /// Artifact kind: `plan` or `pipeline`.
    pub kind: String,
    /// Server-side wall time for this request, milliseconds.
    pub wall_ms: f64,
    /// The artifact body (a `CompiledPlan` or `PipelineSolution` JSON).
    pub artifact: Json,
}

impl RemoteOutcome {
    fn from_json(v: &Json) -> Result<RemoteOutcome> {
        let fingerprint = v
            .get("fingerprint")
            .as_str()
            .ok_or_else(|| anyhow!("response missing \"fingerprint\""))?
            .to_string();
        Ok(RemoteOutcome {
            fingerprint,
            source: v
                .get("source")
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
            kind: v.get("kind").as_str().unwrap_or("plan").to_string(),
            wall_ms: v.get("wall_ms").as_f64().unwrap_or(0.0),
            artifact: v.get("artifact").clone(),
        })
    }

    /// Canonical serialization of the artifact body — comparable across
    /// clients and against `PlanArtifact::to_json().to_string()`.
    pub fn artifact_text(&self) -> String {
        let mut out = String::new();
        write_json(&self.artifact, &mut out);
        out
    }
}

/// The decoded body of a successful `POST /v1/replan` — the plan
/// outcome plus this request's cell-cache counters.
#[derive(Debug, Clone)]
pub struct ReplanOutcome {
    pub outcome: RemoteOutcome,
    /// Cells seeded into the daemon's store from the `from` solution.
    pub cells_seeded: usize,
    /// Stage cells served from the store during this solve.
    pub cells_reused: usize,
    /// Stage cells the solver had to recompile.
    pub cells_recompiled: usize,
}

/// A blocking HTTP client bound to one daemon address.
pub struct Client {
    addr: String,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Client {
        Client { addr: addr.into() }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn get(&self, path: &str) -> Result<(u16, Vec<u8>)> {
        let resp =
            tinyhttp::request(&self.addr, "GET", path, &[], &[])
                .map_err(|e| anyhow!("GET {} {}: {e}", self.addr, path))?;
        let status = resp.status;
        let body = resp
            .read_body()
            .map_err(|e| anyhow!("GET {path}: reading body: {e}"))?;
        Ok((status, body))
    }

    fn post_json(&self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let mut text = String::new();
        write_json(body, &mut text);
        let resp = tinyhttp::request(
            &self.addr,
            "POST",
            path,
            &[("content-type", "application/json")],
            text.as_bytes(),
        )
        .map_err(|e| anyhow!("POST {} {}: {e}", self.addr, path))?;
        let status = resp.status;
        let bytes = resp
            .read_body()
            .map_err(|e| anyhow!("POST {path}: reading body: {e}"))?;
        Ok((status, parse_body(&bytes)?))
    }

    /// `GET /v1/healthz`; errors unless the daemon reports `ok: true`.
    pub fn healthz(&self) -> Result<Json> {
        let (status, bytes) = self.get("/v1/healthz")?;
        let v = parse_body(&bytes)?;
        if status != 200 || v.get("ok").as_bool() != Some(true) {
            return Err(response_error(status, &v));
        }
        Ok(v)
    }

    /// `GET /v1/metrics` — the daemon's Prometheus text exposition
    /// (counters, gauges, and latency histograms), returned verbatim.
    pub fn metrics(&self) -> Result<String> {
        let (status, bytes) = self.get("/v1/metrics")?;
        if status != 200 {
            return Err(response_error(status, &parse_body(&bytes)?));
        }
        String::from_utf8(bytes)
            .map_err(|_| anyhow!("/v1/metrics: non-UTF8 exposition"))
    }

    /// `GET /v1/cache/stats` — the daemon's [`CacheStats`] counters,
    /// including the registry tier.
    ///
    /// [`CacheStats`]: crate::api::CacheStats
    pub fn cache_stats(&self) -> Result<Json> {
        let (status, bytes) = self.get("/v1/cache/stats")?;
        let v = parse_body(&bytes)?;
        if status != 200 {
            return Err(response_error(status, &v));
        }
        Ok(v)
    }

    /// `POST /v1/plan` with one spec.
    pub fn plan(&self, spec: &PlanSpec) -> Result<RemoteOutcome> {
        let (status, v) = self.post_json("/v1/plan", &spec.to_json())?;
        if status != 200 {
            return Err(response_error(status, &v));
        }
        RemoteOutcome::from_json(&v)
    }

    /// `POST /v1/replan`: `spec` plus `from`, the fingerprint of a
    /// registered pipeline solution whose per-stage cells seed the
    /// solve (`automap replan` is the CLI equivalent).
    pub fn replan(
        &self,
        spec: &PlanSpec,
        from: &str,
    ) -> Result<ReplanOutcome> {
        let mut body = spec.to_json();
        if let Json::Obj(map) = &mut body {
            map.insert("from".into(), s(from));
        }
        let (status, v) = self.post_json("/v1/replan", &body)?;
        if status != 200 {
            return Err(response_error(status, &v));
        }
        Ok(ReplanOutcome {
            outcome: RemoteOutcome::from_json(&v)?,
            cells_seeded: v.get("cells_seeded").as_usize().unwrap_or(0),
            cells_reused: v.get("cells_reused").as_usize().unwrap_or(0),
            cells_recompiled: v
                .get("cells_recompiled")
                .as_usize()
                .unwrap_or(0),
        })
    }

    /// `POST /v1/plan` with `{"requests": [...]}`; per-entry outcomes in
    /// input order (a whole-batch rejection is the outer `Err`).
    pub fn plan_batch(
        &self,
        specs: &[PlanSpec],
    ) -> Result<Vec<Result<RemoteOutcome>>> {
        self.plan_batch_job(specs, None)
    }

    /// [`plan_batch`](Client::plan_batch) with an optional top-level
    /// job id: the daemon streams every request's progress events —
    /// including those emitted on its batch worker threads — over one
    /// `GET /v1/events/<job>` channel.
    pub fn plan_batch_job(
        &self,
        specs: &[PlanSpec],
        job: Option<&str>,
    ) -> Result<Vec<Result<RemoteOutcome>>> {
        let mut pairs = vec![(
            "requests",
            arr(specs.iter().map(|sp| sp.to_json()).collect()),
        )];
        if let Some(id) = job {
            pairs.push(("job", s(id)));
        }
        let body = obj(pairs);
        let (status, v) = self.post_json("/v1/plan", &body)?;
        if status != 200 {
            return Err(response_error(status, &v));
        }
        let rows = v
            .get("results")
            .as_arr()
            .ok_or_else(|| anyhow!("batch response missing \"results\""))?;
        Ok(rows
            .iter()
            .map(|row| {
                if !matches!(row.get("error"), Json::Null) {
                    Err(response_error(200, row))
                } else {
                    RemoteOutcome::from_json(row)
                }
            })
            .collect())
    }

    /// `GET /v1/plan/<fingerprint>` — the artifact exactly as the
    /// registry stores it on disk (byte-identity checks compare this).
    pub fn fetch_raw(&self, fingerprint: &str) -> Result<Vec<u8>> {
        let path = format!("/v1/plan/{fingerprint}");
        let (status, bytes) = self.get(&path)?;
        if status != 200 {
            return Err(response_error(status, &parse_body(&bytes)?));
        }
        Ok(bytes)
    }

    /// `GET /v1/plan/<fingerprint>`, parsed.
    pub fn fetch(&self, fingerprint: &str) -> Result<Json> {
        parse_body(&self.fetch_raw(fingerprint)?)
    }

    /// `GET /v1/events/<job>`: consume the chunked progress stream,
    /// calling `f` per event until the job finishes. Returns the event
    /// count.
    pub fn events(
        &self,
        job: &str,
        mut f: impl FnMut(&Json),
    ) -> Result<usize> {
        let path = format!("/v1/events/{job}");
        let mut resp =
            tinyhttp::request(&self.addr, "GET", &path, &[], &[])
                .map_err(|e| anyhow!("GET {path}: {e}"))?;
        if resp.status != 200 {
            let status = resp.status;
            let bytes = resp
                .read_body()
                .map_err(|e| anyhow!("GET {path}: reading body: {e}"))?;
            return Err(response_error(status, &parse_body(&bytes)?));
        }
        let mut count = 0usize;
        let mut pending = String::new();
        while let Some(chunk) = resp
            .next_chunk()
            .map_err(|e| anyhow!("GET {path}: stream: {e}"))?
        {
            pending.push_str(
                std::str::from_utf8(&chunk)
                    .map_err(|_| anyhow!("event stream is not UTF-8"))?,
            );
            while let Some(nl) = pending.find('\n') {
                let line: String = pending.drain(..=nl).collect();
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let ev = Json::parse(line)
                    .map_err(|e| anyhow!("bad event line: {e}"))?;
                f(&ev);
                count += 1;
            }
        }
        Ok(count)
    }
}

fn parse_body(bytes: &[u8]) -> Result<Json> {
    let text = std::str::from_utf8(bytes)
        .map_err(|_| anyhow!("response body is not UTF-8"))?;
    Json::parse(text).map_err(|e| anyhow!("response body: {e}"))
}

/// Surface the server's structured `{"error": {code, message}}` body.
fn response_error(status: u16, v: &Json) -> anyhow::Error {
    let err = v.get("error");
    match (err.get("code").as_str(), err.get("message").as_str()) {
        (Some(code), Some(msg)) => {
            anyhow!("server error {code} (HTTP {status}): {msg}")
        }
        _ => anyhow!("server returned HTTP {status}: {v}"),
    }
}
