//! The daemon: accept loop, request routing, job event channels.
//!
//! One blocking handler thread per connection (bounded in practice by
//! the per-tenant admission caps on the plan endpoints), over a
//! nonblocking accept loop that polls a stop flag every few
//! milliseconds — which is what lets tests (and embedders) start a
//! daemon on an ephemeral port, stop it, and warm-restart another on
//! the same registry, all in-process.
//!
//! Progress streaming: the service has a single global progress
//! callback, so events are routed to per-job channels through a
//! [`ProgressHub`] the handler thread installs around its `plan()` /
//! `plan_batch()` call. The pool propagates the hub into its workers,
//! so events born on batch or pipeline-cell worker threads reach the
//! job's stream too — nothing is dropped for running off-thread.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};
use tinyhttp::{ChunkedWriter, Request, Response};

use crate::api::registry::{KIND_PIPELINE, KIND_PLAN};
use crate::api::{
    Artifact, PipelineSolution, PlanOutcome, PlanService, ProgressHub,
};
use crate::util::json::{arr, num, obj, s, write_json, Json};
use crate::util::pool;

use super::admission::{AdmissionQueue, DEFAULT_TENANT};
use super::wire::{error_json, stats_json, PlanSpec};

/// Poll interval of the nonblocking accept loops.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Retained job channels before finished ones are reaped.
const MAX_JOBS: usize = 256;

/// Daemon configuration (`automap serve` flags).
pub struct ServeConfig {
    /// TCP listen address; port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Optional additional Unix-domain listener.
    pub unix: Option<PathBuf>,
    /// Plan registry directory (created if missing).
    pub registry: PathBuf,
    /// Per-tenant concurrent-plan cap.
    pub max_inflight: usize,
    /// Per-tenant bounded wait queue past the in-flight cap.
    pub max_queued: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:7070".into(),
            unix: None,
            registry: PathBuf::from(".automap-cache"),
            max_inflight: pool::threads(),
            max_queued: 32,
        }
    }
}

/// Per-job progress event channel: the handler thread pushes, the
/// events stream pops; `finish` unblocks a draining reader.
struct JobChannel {
    events: Mutex<VecDeque<Json>>,
    cv: Condvar,
    done: AtomicBool,
}

impl JobChannel {
    fn new() -> JobChannel {
        JobChannel {
            events: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            done: AtomicBool::new(false),
        }
    }

    fn push(&self, ev: Json) {
        self.events.lock().unwrap().push_back(ev);
        self.cv.notify_all();
    }

    fn finish(&self) {
        let _guard = self.events.lock().unwrap();
        self.done.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// Next event, blocking; `None` once finished and drained.
    fn next(&self) -> Option<Json> {
        let mut q = self.events.lock().unwrap();
        loop {
            if let Some(ev) = q.pop_front() {
                return Some(ev);
            }
            if self.done.load(Ordering::SeqCst) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }
}

#[derive(Clone)]
struct JobRegistry(Arc<Mutex<HashMap<String, Arc<JobChannel>>>>);

impl JobRegistry {
    fn new() -> JobRegistry {
        JobRegistry(Arc::new(Mutex::new(HashMap::new())))
    }

    fn register(&self, id: &str) -> Arc<JobChannel> {
        let mut map = self.0.lock().unwrap();
        if map.len() >= MAX_JOBS {
            map.retain(|_, ch| !ch.done.load(Ordering::SeqCst));
        }
        let ch = Arc::new(JobChannel::new());
        map.insert(id.to_string(), Arc::clone(&ch));
        ch
    }

    fn get(&self, id: &str) -> Option<Arc<JobChannel>> {
        self.0.lock().unwrap().get(id).cloned()
    }

    fn remove(&self, id: &str) {
        self.0.lock().unwrap().remove(id);
    }
}

/// Install a [`ProgressHub`] forwarding events into `channel` for the
/// duration of the returned guard (handler thread + pool workers it
/// spawns).
fn install_job_hub(
    channel: &Arc<JobChannel>,
) -> crate::api::HubGuard {
    let ch = Arc::clone(channel);
    ProgressHub::install(ProgressHub::new(move |ev| {
        ch.push(ev.to_json());
    }))
}

struct State {
    service: PlanService,
    admission: AdmissionQueue,
    jobs: JobRegistry,
    registry_dir: PathBuf,
}

impl State {
    fn new(config: &ServeConfig) -> Result<State> {
        let service = PlanService::with_dir(&config.registry)?
            .on_progress(|ev| {
                // the hub is found wherever the event was born: the
                // handler thread, or a pool worker that inherited it.
                // hub.emit taps the metrics registry itself; events with
                // no hub (jobless requests) are tapped here instead, so
                // every event is counted exactly once either way
                match ProgressHub::current() {
                    Some(hub) => hub.emit(ev),
                    None => crate::obs::metrics::record_event(ev),
                }
            });
        Ok(State {
            service,
            admission: AdmissionQueue::new(
                config.max_inflight,
                config.max_queued,
            ),
            jobs: JobRegistry::new(),
            registry_dir: config.registry.clone(),
        })
    }
}

/// A running daemon. Dropping the handle does NOT stop the server; call
/// [`stop`](ServerHandle::stop) (tests) or never (the CLI).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> String {
        self.addr.to_string()
    }

    /// Signal the accept loops, join every handler, release the port.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            t.join().ok();
        }
    }
}

/// Bind and start serving in background threads; returns immediately.
pub fn start(config: ServeConfig) -> Result<ServerHandle> {
    let state = Arc::new(State::new(&config)?);
    let listener = TcpListener::bind(&config.addr)
        .map_err(|e| anyhow!("binding {}: {e}", config.addr))?;
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let mut threads = Vec::new();
    {
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        threads.push(std::thread::spawn(move || {
            accept_tcp(listener, state, stop)
        }));
    }
    #[cfg(unix)]
    if let Some(path) = &config.unix {
        std::fs::remove_file(path).ok();
        let listener = std::os::unix::net::UnixListener::bind(path)
            .map_err(|e| anyhow!("binding {}: {e}", path.display()))?;
        listener.set_nonblocking(true)?;
        let state = Arc::clone(&state);
        let stop = Arc::clone(&stop);
        threads.push(std::thread::spawn(move || {
            accept_unix(listener, state, stop)
        }));
    }
    #[cfg(not(unix))]
    if config.unix.is_some() {
        return Err(anyhow!("--unix requires a unix platform"));
    }
    Ok(ServerHandle { addr, stop, threads })
}

/// `automap serve`: start and serve until the process dies.
pub fn run(config: ServeConfig) -> Result<()> {
    let registry = config.registry.clone();
    let unix = config.unix.clone();
    let handle = start(config)?;
    eprintln!(
        "automap serve: listening on {} (registry {}{})",
        handle.addr(),
        registry.display(),
        unix.map(|p| format!(", unix {}", p.display()))
            .unwrap_or_default()
    );
    loop {
        std::thread::park();
    }
}

fn accept_tcp(
    listener: TcpListener,
    state: Arc<State>,
    stop: Arc<AtomicBool>,
) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nodelay(true).ok();
                let state = Arc::clone(&state);
                handlers.push(std::thread::spawn(move || {
                    let Ok(read_half) = stream.try_clone() else {
                        return;
                    };
                    let mut r = BufReader::new(read_half);
                    let mut w = stream;
                    handle(&state, &mut r, &mut w);
                }));
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    for h in handlers {
        h.join().ok();
    }
}

#[cfg(unix)]
fn accept_unix(
    listener: std::os::unix::net::UnixListener,
    state: Arc<State>,
    stop: Arc<AtomicBool>,
) {
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let state = Arc::clone(&state);
                handlers.push(std::thread::spawn(move || {
                    let Ok(read_half) = stream.try_clone() else {
                        return;
                    };
                    let mut r = BufReader::new(read_half);
                    let mut w = stream;
                    handle(&state, &mut r, &mut w);
                }));
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    for h in handlers {
        h.join().ok();
    }
}

fn json_body(v: &Json) -> Vec<u8> {
    let mut text = String::new();
    write_json(v, &mut text);
    text.push('\n');
    text.into_bytes()
}

/// Write a JSON response; returns `(status, body bytes)` for the access
/// log and the per-endpoint metrics.
fn respond<W: Write>(w: &mut W, status: u16, v: &Json) -> (u16, u64) {
    let body = json_body(v);
    let bytes = body.len() as u64;
    Response::json(body, status).write_to(w).ok();
    (status, bytes)
}

fn outcome_json(out: &PlanOutcome) -> Json {
    obj(vec![
        ("fingerprint", s(&out.fingerprint)),
        ("source", s(out.source.name())),
        ("kind", s(out.artifact.kind())),
        ("wall_ms", num(out.wall_ms)),
        ("artifact", out.artifact.to_json()),
    ])
}

/// Route one request and write one response (or one chunked stream).
///
/// Every routed request leaves three observability trails: a `serve`
/// span, an access-log line on stderr, and per-endpoint counters
/// (`automap_http_requests_total{route,status}` +
/// `automap_http_request_ms{route}`). Route labels are static patterns
/// (`/v1/plan/:fp`, not the fingerprint itself) so metric cardinality
/// stays bounded.
fn handle<R: BufRead, W: Write>(state: &State, r: &mut R, w: &mut W) {
    let t0 = std::time::Instant::now();
    let req = match Request::read_from(r) {
        Ok(rq) => rq,
        Err(e) => {
            respond(
                w,
                400,
                &error_json("bad-request", &e.to_string()),
            );
            return;
        }
    };
    let path = req.path.split('?').next().unwrap_or("").to_string();
    let tenant = req
        .header("x-automap-tenant")
        .unwrap_or("-")
        .to_string();
    let mut sp = crate::obs::trace::span(
        format!("{} {path}", req.method),
        "serve",
    );
    let (route, (status, bytes)) = match (req.method.as_str(), path.as_str())
    {
        ("GET", "/v1/healthz") => (
            "/v1/healthz",
            respond(
                w,
                200,
                &obj(vec![
                    ("ok", Json::Bool(true)),
                    ("service", s("automap-serve")),
                    (
                        "registry",
                        s(&state.registry_dir.display().to_string()),
                    ),
                ]),
            ),
        ),
        ("GET", "/v1/metrics") => {
            ("/v1/metrics", handle_metrics(state, w))
        }
        ("GET", "/v1/cache/stats") => (
            "/v1/cache/stats",
            respond(w, 200, &stats_json(&state.service.stats())),
        ),
        ("GET", p) if p.starts_with("/v1/plan/") => (
            "/v1/plan/:fp",
            handle_fetch(state, w, &p["/v1/plan/".len()..]),
        ),
        ("GET", p) if p.starts_with("/v1/events/") => (
            "/v1/events/:job",
            handle_events(state, w, &p["/v1/events/".len()..]),
        ),
        ("POST", "/v1/plan") => {
            ("/v1/plan", handle_plan(state, w, &req))
        }
        ("POST", "/v1/replan") => {
            ("/v1/replan", handle_replan(state, w, &req))
        }
        (_, "/v1/plan")
        | (_, "/v1/replan")
        | (_, "/v1/healthz")
        | (_, "/v1/metrics")
        | (_, "/v1/cache/stats") => (
            "method-not-allowed",
            respond(
                w,
                405,
                &error_json(
                    "method-not-allowed",
                    &format!("{} {} is not supported", req.method, path),
                ),
            ),
        ),
        _ => (
            "other",
            respond(
                w,
                404,
                &error_json(
                    "not-found",
                    &format!(
                        "no route for {} {} (see /v1/healthz, /v1/plan, \
                         /v1/replan, /v1/plan/<fingerprint>, \
                         /v1/events/<job>, /v1/cache/stats, /v1/metrics)",
                        req.method, path
                    ),
                ),
            ),
        ),
    };
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let status_str = status.to_string();
    crate::obs::metrics::inc(
        "automap_http_requests_total",
        &[("route", route), ("status", &status_str)],
        1,
    );
    crate::obs::metrics::observe_ms(
        "automap_http_request_ms",
        &[("route", route)],
        ms,
    );
    sp.arg("status", num(status as f64));
    sp.arg("bytes", num(bytes as f64));
    drop(sp);
    crate::info!(
        "{} {} {} {}B tenant={} {:.1}ms",
        req.method,
        path,
        status,
        bytes,
        tenant,
        ms
    );
}

/// `GET /v1/metrics`: Prometheus text exposition of every counter,
/// gauge, and histogram, with the live cache/registry totals folded
/// into their gauges at scrape time.
fn handle_metrics<W: Write>(state: &State, w: &mut W) -> (u16, u64) {
    crate::obs::metrics::sync_cache_stats(&state.service.stats());
    let body = crate::obs::metrics::expose().into_bytes();
    let bytes = body.len() as u64;
    Response::new(200)
        .header("content-type", "text/plain; version=0.0.4")
        .body(body)
        .write_to(w)
        .ok();
    (200, bytes)
}

/// `GET /v1/plan/<fingerprint>`: the registered artifact, byte-for-byte
/// as the registry stores it.
fn handle_fetch<W: Write>(
    state: &State,
    w: &mut W,
    fp: &str,
) -> (u16, u64) {
    let Some(reg) = state.service.cache().registry() else {
        return respond(
            w,
            500,
            &error_json("no-registry", "daemon has no registry tier"),
        );
    };
    for kind in [KIND_PLAN, KIND_PIPELINE] {
        if let Some(bytes) = reg.load(fp, kind) {
            let n = bytes.len() as u64;
            Response::json(bytes, 200)
                .header("x-automap-kind", kind)
                .write_to(w)
                .ok();
            return (200, n);
        }
    }
    respond(
        w,
        404,
        &error_json(
            "not-found",
            &format!("no plan or pipeline artifact for {fp}"),
        ),
    )
}

/// `GET /v1/events/<job>`: chunked stream, one event JSON per line.
fn handle_events<W: Write>(
    state: &State,
    w: &mut W,
    job: &str,
) -> (u16, u64) {
    let Some(ch) = state.jobs.get(job) else {
        return respond(
            w,
            404,
            &error_json("not-found", &format!("unknown job '{job}'")),
        );
    };
    let mut sent = 0u64;
    let mut cw = ChunkedWriter::new(w, 200)
        .header("content-type", "application/json");
    while let Some(ev) = ch.next() {
        let mut line = String::new();
        write_json(&ev, &mut line);
        line.push('\n');
        if cw.chunk(line.as_bytes()).is_err() {
            break; // client hung up; keep draining nothing
        }
        sent += line.len() as u64;
    }
    cw.finish().ok();
    state.jobs.remove(job);
    (200, sent)
}

fn tenant_of(req: &Request, spec: Option<&PlanSpec>) -> String {
    req.header("x-automap-tenant")
        .map(str::to_string)
        .or_else(|| spec.and_then(|sp| sp.tenant.clone()))
        .unwrap_or_else(|| DEFAULT_TENANT.to_string())
}

/// `POST /v1/plan`: a single spec object, or `{"requests": [...]}`.
fn handle_plan<W: Write>(
    state: &State,
    w: &mut W,
    req: &Request,
) -> (u16, u64) {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            return respond(
                w,
                400,
                &error_json("bad-request", "body is not UTF-8"),
            );
        }
    };
    let body = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return respond(
                w,
                400,
                &error_json("bad-request", &format!("body: {e}")),
            );
        }
    };
    if let Some(items) = body.get("requests").as_arr() {
        return handle_plan_batch(state, w, req, &body, items);
    }
    let spec = match PlanSpec::from_json(&body) {
        Ok(sp) => sp,
        Err(e) => {
            return respond(
                w,
                400,
                &error_json("bad-request", &e.to_string()),
            );
        }
    };
    let tenant = tenant_of(req, Some(&spec));
    let permit = match state.admission.enter(&tenant) {
        Ok(p) => p,
        Err(rej) => {
            crate::obs::metrics::inc(
                "automap_admission_rejections_total",
                &[("tenant", &tenant)],
                1,
            );
            return respond(
                w,
                429,
                &error_json(
                    "over-capacity",
                    &format!(
                        "tenant '{}' has {} plan(s) in flight and {} \
                         queued; retry later",
                        rej.tenant, rej.inflight, rej.queued
                    ),
                ),
            );
        }
    };
    let channel = spec.job.as_deref().map(|id| state.jobs.register(id));
    let guard = channel.as_ref().map(install_job_hub);
    let result = spec
        .resolve()
        .and_then(|plan_req| state.service.plan(&plan_req));
    drop(guard);
    if let Some(ch) = &channel {
        ch.finish();
    }
    drop(permit);
    match result {
        Ok(out) => respond(w, 200, &outcome_json(&out)),
        Err(e) => respond(
            w,
            500,
            &error_json("plan-failed", &e.to_string()),
        ),
    }
}

/// `POST /v1/replan`: a plan spec plus `"from": "<fingerprint>"` naming
/// a registered pipeline solution. The previous solution's per-stage
/// cells are seeded into the service-wide [`CellStore`], then the spec
/// plans normally — stages whose content fingerprint still matches the
/// new cluster are reused instead of recompiled. The response is the
/// `/v1/plan` envelope plus `cells_seeded` / `cells_reused` /
/// `cells_recompiled` counters for this request.
///
/// [`CellStore`]: crate::api::CellStore
fn handle_replan<W: Write>(
    state: &State,
    w: &mut W,
    req: &Request,
) -> (u16, u64) {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            return respond(
                w,
                400,
                &error_json("bad-request", "body is not UTF-8"),
            );
        }
    };
    let body = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return respond(
                w,
                400,
                &error_json("bad-request", &format!("body: {e}")),
            );
        }
    };
    let Some(from) = body.get("from").as_str().map(str::to_string)
    else {
        return respond(
            w,
            400,
            &error_json(
                "bad-request",
                "replan needs \"from\": the fingerprint of a \
                 registered pipeline solution",
            ),
        );
    };
    let spec = match PlanSpec::from_json(&body) {
        Ok(sp) => sp,
        Err(e) => {
            return respond(
                w,
                400,
                &error_json("bad-request", &e.to_string()),
            );
        }
    };
    if spec.pp.is_none() {
        return respond(
            w,
            400,
            &error_json(
                "bad-request",
                "replan is a pipeline operation; the spec needs a \
                 \"pp\" object",
            ),
        );
    }
    let Some(reg) = state.service.cache().registry() else {
        return respond(
            w,
            500,
            &error_json("no-registry", "daemon has no registry tier"),
        );
    };
    let Some(bytes) = reg.load(&from, KIND_PIPELINE) else {
        return respond(
            w,
            404,
            &error_json(
                "not-found",
                &format!("no pipeline solution registered under {from}"),
            ),
        );
    };
    let prev = match std::str::from_utf8(&bytes)
        .map_err(|_| anyhow!("artifact is not UTF-8"))
        .and_then(|t| {
            Json::parse(t).map_err(|e| anyhow!("parse: {e}"))
        })
        .and_then(|v| PipelineSolution::from_json(&v))
    {
        Ok(p) => p,
        Err(e) => {
            return respond(
                w,
                500,
                &error_json(
                    "bad-artifact",
                    &format!("loading {from}: {e}"),
                ),
            );
        }
    };
    let tenant = tenant_of(req, Some(&spec));
    let permit = match state.admission.enter(&tenant) {
        Ok(p) => p,
        Err(rej) => {
            crate::obs::metrics::inc(
                "automap_admission_rejections_total",
                &[("tenant", &tenant)],
                1,
            );
            return respond(
                w,
                429,
                &error_json(
                    "over-capacity",
                    &format!(
                        "tenant '{}' has {} plan(s) in flight and {} \
                         queued; retry later",
                        rej.tenant, rej.inflight, rej.queued
                    ),
                ),
            );
        }
    };
    let cells = state.service.cell_store();
    let seeded = cells.seed_solution(&prev);
    let (reused0, recompiled0) = (cells.reused(), cells.recompiled());
    let channel = spec.job.as_deref().map(|id| state.jobs.register(id));
    let guard = channel.as_ref().map(install_job_hub);
    let result = spec.resolve().and_then(|mut plan_req| {
        // a replanned job keeps the original budget unless the spec
        // overrides it: cell fingerprints include the budget, so a
        // different default would silently force a full recompile
        if spec.budget_gb.is_none() && prev.budget > 0.0 {
            plan_req.opts.budget = Some(prev.budget);
        }
        state.service.plan(&plan_req)
    });
    drop(guard);
    if let Some(ch) = &channel {
        ch.finish();
    }
    drop(permit);
    match result {
        Ok(out) => respond(
            w,
            200,
            &obj(vec![
                ("fingerprint", s(&out.fingerprint)),
                ("source", s(out.source.name())),
                ("kind", s(out.artifact.kind())),
                ("wall_ms", num(out.wall_ms)),
                ("cells_seeded", num(seeded as f64)),
                (
                    "cells_reused",
                    num((cells.reused() - reused0) as f64),
                ),
                (
                    "cells_recompiled",
                    num((cells.recompiled() - recompiled0) as f64),
                ),
                ("artifact", out.artifact.to_json()),
            ]),
        ),
        Err(e) => respond(
            w,
            500,
            &error_json("plan-failed", &e.to_string()),
        ),
    }
}

/// `{"requests": [...], "job": "<id>"}` — the optional top-level `job`
/// streams every request's progress events (including those born on
/// batch worker threads) over one `GET /v1/events/<id>` channel.
fn handle_plan_batch<W: Write>(
    state: &State,
    w: &mut W,
    req: &Request,
    body: &Json,
    items: &[Json],
) -> (u16, u64) {
    let tenant = tenant_of(req, None);
    let permit = match state.admission.enter(&tenant) {
        Ok(p) => p,
        Err(rej) => {
            crate::obs::metrics::inc(
                "automap_admission_rejections_total",
                &[("tenant", &tenant)],
                1,
            );
            return respond(
                w,
                429,
                &error_json(
                    "over-capacity",
                    &format!(
                        "tenant '{}' has {} plan(s) in flight and {} \
                         queued; retry later",
                        rej.tenant, rej.inflight, rej.queued
                    ),
                ),
            );
        }
    };
    // resolve what resolves; per-entry failures become per-entry errors
    let mut resolved: Vec<(usize, crate::api::PlanRequest)> = Vec::new();
    let mut slots: Vec<Option<Json>> = vec![None; items.len()];
    for (i, item) in items.iter().enumerate() {
        match PlanSpec::from_json(item).and_then(|sp| sp.resolve()) {
            Ok(plan_req) => resolved.push((i, plan_req)),
            Err(e) => {
                slots[i] =
                    Some(error_json("bad-request", &e.to_string()));
            }
        }
    }
    let reqs: Vec<crate::api::PlanRequest> =
        resolved.iter().map(|(_, r)| r.clone()).collect();
    let channel = body
        .get("job")
        .as_str()
        .map(|id| state.jobs.register(id));
    let guard = channel.as_ref().map(install_job_hub);
    let results = state.service.plan_batch(&reqs);
    drop(guard);
    if let Some(ch) = &channel {
        ch.finish();
    }
    for ((i, _), r) in resolved.iter().zip(results) {
        slots[*i] = Some(match r {
            Ok(out) => outcome_json(&out),
            Err(e) => error_json("plan-failed", &e.to_string()),
        });
    }
    drop(permit);
    let rows: Vec<Json> =
        slots.into_iter().map(|v| v.expect("slot filled")).collect();
    respond(w, 200, &obj(vec![("results", arr(rows))]))
}
