//! The daemon's wire format: request specs and response shapes.
//!
//! A [`PlanSpec`] is exactly one `automap batch` manifest entry — model,
//! cluster and backend by *name* plus the scalar options. The server
//! resolves it to a full [`PlanRequest`] (rebuilding the graph from the
//! model name), so requests stay a few hundred bytes and the fingerprint
//! the server computes matches what `automap plan` computes locally for
//! the same flags. `model_for`/`cluster_for` are the single naming
//! authority — the CLI resolves through these same functions.

use anyhow::{anyhow, Result};

use crate::api::{
    BackendSpec, CacheStats, PlanOpts, PlanRequest, PpOpts, Schedule,
};
use crate::cluster::SimCluster;
use crate::graph::models::{gpt2, Gpt2Cfg};
use crate::sim::DeviceModel;
use crate::solver::SolveOpts;
use crate::util::json::{arr, num, obj, s, Json};

/// Resolve a model name (`gpt2-mini|mini|alpha..delta`).
pub fn model_for(name: &str) -> Result<Gpt2Cfg> {
    Ok(match name {
        "gpt2-mini" | "mini" => Gpt2Cfg::mini(),
        "alpha" | "beta" | "gamma" | "delta" => Gpt2Cfg::paper(name),
        other => {
            return Err(anyhow!(
                "unknown model {other} (gpt2-mini|alpha..delta)"
            ))
        }
    })
}

/// Resolve a cluster name (`fig5|single|nvlink<N>|multinode<NxM>`, plus
/// the elastic/heterogeneous fig5 scenarios `fig5-prefix<N>`,
/// `fig5-drop<I>`, `fig5-grow`, `fig5-degraded`, `fig5-mixed` used by
/// `automap replan` and the replan bench).
pub fn cluster_for(name: &str) -> Result<SimCluster> {
    if name == "fig5" {
        Ok(SimCluster::partially_connected_8gpu())
    } else if name == "single" {
        Ok(SimCluster::single())
    } else if name == "fig5-grow" {
        Ok(SimCluster::fig5_grow())
    } else if name == "fig5-degraded" {
        Ok(SimCluster::fig5_degraded())
    } else if name == "fig5-mixed" {
        Ok(SimCluster::fig5_mixed())
    } else if let Some(n) = name.strip_prefix("fig5-prefix") {
        let n = n
            .parse()
            .map_err(|_| anyhow!("fig5-prefix<N> wants an integer, got {n}"))?;
        Ok(SimCluster::fig5_prefix(n))
    } else if let Some(i) = name.strip_prefix("fig5-drop") {
        let i = i
            .parse()
            .map_err(|_| anyhow!("fig5-drop<I> wants a device id, got {i}"))?;
        Ok(SimCluster::fig5_drop(i))
    } else if let Some(n) = name.strip_prefix("nvlink") {
        let n = n
            .parse()
            .map_err(|_| anyhow!("nvlink<N> needs an integer, got {n}"))?;
        Ok(SimCluster::fully_connected(n))
    } else if let Some(spec) = name.strip_prefix("multinode") {
        let (a, b) = spec
            .split_once('x')
            .ok_or_else(|| anyhow!("multinode<N>x<M>, got {spec}"))?;
        Ok(SimCluster::multi_node(
            a.parse().map_err(|_| anyhow!("bad node count {a}"))?,
            b.parse().map_err(|_| anyhow!("bad per-node count {b}"))?,
            100.0,
        ))
    } else {
        Err(anyhow!(
            "unknown cluster {name} (fig5|fig5-prefix<N>|fig5-drop<I>|\
             fig5-grow|fig5-degraded|fig5-mixed|single|nvlink<N>|\
             multinode<NxM>)"
        ))
    }
}

/// One planning request on the wire. Identical field names and defaults
/// to an `automap batch` manifest entry, plus the daemon-only `tenant`
/// and `job` routing fields.
#[derive(Debug, Clone)]
pub struct PlanSpec {
    /// Display label (not part of the fingerprint).
    pub tag: Option<String>,
    pub model: String,
    pub cluster: String,
    pub backend: String,
    pub fast: bool,
    pub budget_gb: Option<f64>,
    pub sweep: Option<usize>,
    pub seed: Option<u64>,
    /// Two-level pipeline planning options (`--pp`).
    pub pp: Option<PpOpts>,
    /// Admission-queue tenant (also settable via `x-automap-tenant`).
    pub tenant: Option<String>,
    /// Progress-stream job id: events emitted while this request plans
    /// are published under `GET /v1/events/<job>`.
    pub job: Option<String>,
}

impl PlanSpec {
    pub fn new(model: impl Into<String>, cluster: impl Into<String>) -> PlanSpec {
        PlanSpec {
            tag: None,
            model: model.into(),
            cluster: cluster.into(),
            backend: "beam".into(),
            fast: false,
            budget_gb: None,
            sweep: None,
            seed: None,
            pp: None,
            tenant: None,
            job: None,
        }
    }

    pub fn from_json(v: &Json) -> Result<PlanSpec> {
        if v.as_obj().is_none() {
            return Err(anyhow!("plan spec must be a JSON object"));
        }
        let pp = match v.get("pp") {
            Json::Null => None,
            ppv => {
                if ppv.as_obj().is_none() {
                    return Err(anyhow!("\"pp\" must be an object"));
                }
                let mut pp = PpOpts::default();
                if let Some(k) = ppv.get("max_stages").as_usize() {
                    pp.max_stages = k;
                }
                if let Some(k) = ppv.get("min_stages").as_usize() {
                    pp.min_stages = k;
                }
                if let Some(b) = ppv.get("balance").as_f64() {
                    pp.balance = b;
                }
                if let Some(mb) = ppv.get("microbatches").usize_vec() {
                    pp.microbatches = mb;
                }
                // absent => the default zoo; present => forced list
                if let Some(list) = ppv.get("schedule").as_arr() {
                    pp.schedule = list
                        .iter()
                        .map(|x| {
                            x.as_str()
                                .ok_or_else(|| {
                                    anyhow!(
                                        "pp.schedule entries must be \
                                         strings"
                                    )
                                })
                                .and_then(Schedule::parse)
                        })
                        .collect::<Result<Vec<Schedule>>>()?;
                }
                Some(pp)
            }
        };
        Ok(PlanSpec {
            tag: v.get("tag").as_str().map(str::to_string),
            model: v
                .get("model")
                .as_str()
                .unwrap_or("gpt2-mini")
                .to_string(),
            cluster: v
                .get("cluster")
                .as_str()
                .unwrap_or("fig5")
                .to_string(),
            backend: v
                .get("backend")
                .as_str()
                .unwrap_or("beam")
                .to_string(),
            fast: v.get("fast").as_bool().unwrap_or(false),
            budget_gb: v.get("budget_gb").as_f64(),
            sweep: v.get("sweep").as_usize(),
            seed: v.get("seed").as_u64(),
            pp,
            tenant: v.get("tenant").as_str().map(str::to_string),
            job: v.get("job").as_str().map(str::to_string),
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("model", s(&self.model)),
            ("cluster", s(&self.cluster)),
            ("backend", s(&self.backend)),
        ];
        if let Some(tag) = &self.tag {
            pairs.push(("tag", s(tag)));
        }
        if self.fast {
            pairs.push(("fast", Json::Bool(true)));
        }
        if let Some(gb) = self.budget_gb {
            pairs.push(("budget_gb", num(gb)));
        }
        if let Some(sw) = self.sweep {
            pairs.push(("sweep", num(sw as f64)));
        }
        if let Some(seed) = self.seed {
            pairs.push(("seed", num(seed as f64)));
        }
        if let Some(pp) = &self.pp {
            pairs.push((
                "pp",
                obj(vec![
                    ("max_stages", num(pp.max_stages as f64)),
                    ("min_stages", num(pp.min_stages as f64)),
                    ("balance", num(pp.balance)),
                    (
                        "microbatches",
                        arr(pp
                            .microbatches
                            .iter()
                            .map(|&x| num(x as f64))
                            .collect()),
                    ),
                    (
                        "schedule",
                        arr(pp
                            .schedule
                            .iter()
                            .map(|sc| s(&sc.name()))
                            .collect()),
                    ),
                ]),
            ));
        }
        if let Some(t) = &self.tenant {
            pairs.push(("tenant", s(t)));
        }
        if let Some(j) = &self.job {
            pairs.push(("job", s(j)));
        }
        obj(pairs)
    }

    /// The display tag: explicit, or `model@cluster/backend`.
    pub fn tag(&self) -> String {
        self.tag.clone().unwrap_or_else(|| {
            format!("{}@{}/{}", self.model, self.cluster, self.backend)
        })
    }

    /// Resolve to a full [`PlanRequest`]: rebuild the graph from the
    /// model name, parse the backend, assemble `PlanOpts` with the same
    /// precedence the CLI and the batch manifest use.
    pub fn resolve(&self) -> Result<PlanRequest> {
        let cfg = model_for(&self.model)?;
        let mut opts = PlanOpts::default();
        if self.fast {
            opts.sweep = 3;
            opts.solve = SolveOpts {
                beam_width: 16,
                anneal_iters: 300,
                lagrange_iters: 6,
                ..Default::default()
            };
        }
        if let Some(gb) = self.budget_gb {
            opts.budget = Some(gb * 1e9);
        }
        if let Some(sw) = self.sweep {
            opts.sweep = sw;
        }
        if let Some(seed) = self.seed {
            opts.seed = seed;
        }
        opts.pp = self.pp.clone();
        let backend = BackendSpec::parse(&self.backend, cfg, opts.solve)?;
        Ok(PlanRequest::new(
            self.tag(),
            gpt2(&cfg),
            cluster_for(&self.cluster)?,
            DeviceModel::a100_80gb(),
        )
        .with_opts(opts)
        .with_backend(backend))
    }
}

/// The structured error body every non-2xx response carries:
/// `{"error": {"code": .., "message": ..}}`.
pub fn error_json(code: &str, message: &str) -> Json {
    obj(vec![(
        "error",
        obj(vec![("code", s(code)), ("message", s(message))]),
    )])
}

/// `GET /v1/cache/stats` body (also `automap cache stats --json`).
pub fn stats_json(st: &CacheStats) -> Json {
    obj(vec![
        ("memory_hits", num(st.memory_hits as f64)),
        ("disk_hits", num(st.disk_hits as f64)),
        ("partial_resumes", num(st.partial_resumes as f64)),
        ("misses", num(st.misses as f64)),
        ("evictions", num(st.evictions as f64)),
        ("sgraph_builds", num(st.sgraph_builds as f64)),
        ("sgraph_reuses", num(st.sgraph_reuses as f64)),
        ("cell_reuses", num(st.cell_reuses as f64)),
        ("cell_recompiles", num(st.cell_recompiles as f64)),
        ("registry_artifacts", num(st.registry_artifacts as f64)),
        ("registry_bytes", num(st.registry_bytes as f64)),
        (
            "registry_gc_evictions",
            num(st.registry_gc_evictions as f64),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrips() {
        let mut spec = PlanSpec::new("gpt2-mini", "nvlink2");
        spec.fast = true;
        spec.budget_gb = Some(40.0);
        // u64::MAX probes the old `as_usize().map(|x| x as u64)` path,
        // which truncated any seed the f64->usize cast couldn't carry
        spec.seed = Some(u64::MAX);
        spec.pp = Some(PpOpts {
            max_stages: 2,
            schedule: vec![Schedule::Interleaved { v: 2 }],
            ..Default::default()
        });
        spec.tenant = Some("team-a".into());
        let back = PlanSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.model, "gpt2-mini");
        assert_eq!(back.cluster, "nvlink2");
        assert!(back.fast);
        assert_eq!(back.budget_gb, Some(40.0));
        assert_eq!(back.seed, Some(u64::MAX));
        assert_eq!(back.pp.as_ref().unwrap().max_stages, 2);
        assert_eq!(
            back.pp.as_ref().unwrap().schedule,
            vec![Schedule::Interleaved { v: 2 }]
        );
        assert_eq!(back.tenant.as_deref(), Some("team-a"));
        assert_eq!(
            back.to_json().to_string(),
            spec.to_json().to_string()
        );
    }

    #[test]
    fn resolve_matches_local_fingerprint() {
        use crate::api::PlanService;
        let spec = PlanSpec::new("gpt2-mini", "nvlink2");
        let a = PlanService::fingerprint(&spec.resolve().unwrap());
        let b = PlanService::fingerprint(&spec.resolve().unwrap());
        assert_eq!(a, b, "spec resolution must be deterministic");
    }

    #[test]
    fn elastic_cluster_names_resolve() {
        assert_eq!(cluster_for("fig5-drop7").unwrap().n, 7);
        assert_eq!(cluster_for("fig5-prefix4").unwrap().n, 4);
        assert_eq!(cluster_for("fig5-grow").unwrap().n, 10);
        let deg = cluster_for("fig5-degraded").unwrap();
        assert_eq!(deg.compute_scale[7], 0.5);
        assert!(cluster_for("fig5-mixed").is_ok());
        assert!(cluster_for("fig5-dropX").is_err());
    }

    #[test]
    fn unknown_names_are_errors() {
        assert!(model_for("gpt9").is_err());
        assert!(cluster_for("torus").is_err());
        assert!(PlanSpec::from_json(&Json::Num(3.0)).is_err());
    }
}
