//! `automap serve` — the multi-tenant planning daemon.
//!
//! Colossal-Auto's value is ahead-of-time compilation: a solved (model,
//! cluster, opts) triple is a reusable artifact, so the expensive solves
//! should happen once and be served everywhere. This module exposes the
//! process-local [`PlanService`](crate::api::PlanService) as a long-lived
//! HTTP daemon over a persistent
//! [`PlanRegistry`](crate::api::PlanRegistry): plans solved in any prior
//! run of the daemon (or by `automap plan --cache-dir` against the same
//! directory) are served byte-identically from disk without invoking any
//! solver backend.
//!
//! ```text
//! automap serve --addr 127.0.0.1:7070 --registry .automap-cache
//!
//! POST /v1/plan               plan one spec, or {"requests": [...]} batch
//! POST /v1/replan             replan a registered pipeline solution
//!                             ("from": fingerprint) on a new cluster,
//!                             reusing its cached stage cells
//! GET  /v1/plan/<fingerprint> fetch a registered artifact verbatim
//! GET  /v1/events/<job>       stream ProgressEvents (chunked)
//! GET  /v1/cache/stats        CacheStats + registry counters
//! GET  /v1/healthz            liveness
//! ```
//!
//! The wire format ([`wire::PlanSpec`]) is the `automap batch` manifest
//! entry: the server rebuilds the graph from the model *name*, so a plan
//! request is a few hundred bytes, and the fingerprint computed on the
//! server is the same one `automap plan` computes locally. Per-tenant
//! admission ([`admission`]) bounds concurrent solves and queue depth per
//! `x-automap-tenant`; identical fingerprints racing across tenants still
//! collapse to one solve via the service's single-flight dedup.
//!
//! [`client::Client`] is the matching blocking client, used by
//! `automap plan --remote <addr>` and the loopback tests — both sides of
//! the wire live in this crate, so a format drift breaks the build, not
//! production.

pub mod admission;
pub mod client;
pub mod server;
pub mod wire;

pub use self::admission::{AdmissionQueue, Permit};
pub use self::client::{Client, RemoteOutcome, ReplanOutcome};
pub use self::server::{ServeConfig, ServerHandle};
pub use self::wire::PlanSpec;
