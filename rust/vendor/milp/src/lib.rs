//! Self-contained 0/1 mixed-integer linear programming solver.
//!
//! Two layers:
//!
//! * [`solve_lp`] — bounded-variable primal simplex on a dense tableau.
//!   Two-phase (artificials are driven out or their redundant rows
//!   dropped), per-variable `[lb, ub]` handled by shifting plus column
//!   complement flips (`x := ub - x`) so every nonbasic variable sits at
//!   zero and no extra bound rows are needed. Dantzig pricing with a
//!   Bland's-rule fallback against cycling.
//! * [`solve`] — branch-and-bound on fractional *binary* variables with
//!   best-bound node selection (min-heap on the parent LP bound), an
//!   optional warm-start incumbent, and a wall-clock/node budget. Any
//!   early exit returns the incumbent, so a warm-started solve is an
//!   anytime improver: the answer never gets worse than the seed.
//!
//! Written for an offline environment (crates.io unreachable): std only,
//! no dependencies. Minimization throughout — negate the objective to
//! maximize.

use std::collections::BinaryHeap;
use std::time::{Duration, Instant};

/// Anti-degeneracy / zero threshold for tableau entries.
const EPS: f64 = 1e-9;
/// Row-level feasibility tolerance (scaled by the rhs magnitude).
const FEAS_TOL: f64 = 1e-6;
/// A binary LP value within this of an integer counts as integral.
const INT_TOL: f64 = 1e-6;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Cmp {
    Le,
    Eq,
    Ge,
}

#[derive(Clone, Debug)]
pub struct Constraint {
    /// `(var, coefficient)` pairs; duplicate vars are summed.
    pub terms: Vec<(usize, f64)>,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// `min c.x  s.t.  rows, lb <= x <= ub`, some vars flagged binary.
#[derive(Clone, Debug, Default)]
pub struct Problem {
    pub objective: Vec<f64>,
    pub lower: Vec<f64>,
    /// `f64::INFINITY` means unbounded above.
    pub upper: Vec<f64>,
    /// Branch-and-bound only branches on these.
    pub binary: Vec<bool>,
    pub constraints: Vec<Constraint>,
}

impl Problem {
    pub fn new() -> Problem {
        Problem::default()
    }

    pub fn add_var(&mut self, obj: f64, lower: f64, upper: f64) -> usize {
        let i = self.objective.len();
        self.objective.push(obj);
        self.lower.push(lower);
        self.upper.push(upper);
        self.binary.push(false);
        i
    }

    /// A `{0, 1}` variable branch-and-bound may branch on.
    pub fn add_binary(&mut self, obj: f64) -> usize {
        let i = self.add_var(obj, 0.0, 1.0);
        self.binary[i] = true;
        i
    }

    pub fn num_vars(&self) -> usize {
        self.objective.len()
    }

    pub fn constrain(
        &mut self,
        terms: Vec<(usize, f64)>,
        cmp: Cmp,
        rhs: f64,
    ) {
        self.constraints.push(Constraint { terms, cmp, rhs });
    }

    pub fn eval(&self, x: &[f64]) -> f64 {
        self.objective.iter().zip(x).map(|(c, v)| c * v).sum()
    }

    /// Loose feasibility check: bounds, binary integrality, every row.
    pub fn is_feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.num_vars() {
            return false;
        }
        for j in 0..x.len() {
            if x[j] < self.lower[j] - tol || x[j] > self.upper[j] + tol {
                return false;
            }
            if self.binary[j] && (x[j] - x[j].round()).abs() > tol {
                return false;
            }
        }
        self.constraints.iter().all(|c| {
            let lhs: f64 = c.terms.iter().map(|&(j, a)| a * x[j]).sum();
            let t = tol * (1.0 + c.rhs.abs());
            match c.cmp {
                Cmp::Le => lhs <= c.rhs + t,
                Cmp::Ge => lhs >= c.rhs - t,
                Cmp::Eq => (lhs - c.rhs).abs() <= t,
            }
        })
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
    /// Iteration safety cap hit — the returned point is feasible but its
    /// objective is NOT a valid lower bound.
    IterLimit,
}

#[derive(Clone, Debug)]
pub struct LpSolution {
    pub status: LpStatus,
    /// Full-length variable vector (empty unless Optimal/IterLimit).
    pub x: Vec<f64>,
    pub objective: f64,
}

/// Solve the LP relaxation (integrality flags ignored).
pub fn solve_lp(p: &Problem) -> LpSolution {
    solve_lp_bounds(p, &p.lower, &p.upper)
}

/// [`solve_lp`] with overriding bounds — how branch-and-bound fixes
/// binaries (`lb = ub = v`) without rebuilding the [`Problem`].
pub fn solve_lp_bounds(
    p: &Problem,
    lower: &[f64],
    upper: &[f64],
) -> LpSolution {
    match Simplex::build(p, lower, upper) {
        Ok(mut s) => s.run(),
        Err(status) => LpSolution {
            status,
            x: Vec::new(),
            objective: f64::INFINITY,
        },
    }
}

/// Dense bounded-variable tableau. Column layout: structurals (free
/// vars, shifted so lb = 0), then slacks/surpluses, then artificials.
struct Simplex<'a> {
    p: &'a Problem,
    lower: &'a [f64],
    /// Problem var index per structural column.
    free: Vec<usize>,
    /// Values of vars substituted out (`ub - lb <= EPS`).
    fixed_val: Vec<f64>,
    m: usize,
    /// Total columns (tableau rows have `n + 1` entries, rhs last).
    n: usize,
    /// First artificial column.
    art0: usize,
    a: Vec<Vec<f64>>,
    /// Reduced-cost row, length `n + 1`; objective excess is `-z[n]`.
    z: Vec<f64>,
    basis: Vec<usize>,
    in_basis: Vec<bool>,
    /// Per-column upper bound in shifted space (INFINITY allowed).
    ub: Vec<f64>,
    /// Column currently complemented (`x = ub - x~`).
    flipped: Vec<bool>,
    /// Objective constant from lb shifts + substituted-out vars.
    obj_base: f64,
    /// Largest |rhs| seen at build time, for the phase-1 tolerance.
    rhs_scale: f64,
}

impl<'a> Simplex<'a> {
    fn build(
        p: &'a Problem,
        lower: &'a [f64],
        upper: &'a [f64],
    ) -> Result<Simplex<'a>, LpStatus> {
        let nv = p.num_vars();
        let mut fixed_val = vec![0.0; nv];
        let mut col_of = vec![usize::MAX; nv];
        let mut free = Vec::new();
        for j in 0..nv {
            if lower[j] > upper[j] + FEAS_TOL {
                return Err(LpStatus::Infeasible);
            }
            if upper[j] - lower[j] <= EPS {
                fixed_val[j] = lower[j];
            } else {
                col_of[j] = free.len();
                free.push(j);
            }
        }
        let nf = free.len();
        let m = p.constraints.len();

        // rows over structural columns, rhs shifted by fixed values and
        // lower bounds; slack sign per row (0 for Eq)
        let mut rows: Vec<Vec<f64>> = vec![vec![0.0; nf]; m];
        let mut rhs = vec![0.0; m];
        let mut slack_sign = vec![0.0f64; m];
        for (r, c) in p.constraints.iter().enumerate() {
            rhs[r] = c.rhs;
            for &(j, coef) in &c.terms {
                if col_of[j] == usize::MAX {
                    rhs[r] -= coef * fixed_val[j];
                } else {
                    rows[r][col_of[j]] += coef;
                    rhs[r] -= coef * lower[j];
                }
            }
            slack_sign[r] = match c.cmp {
                Cmp::Le => 1.0,
                Cmp::Ge => -1.0,
                Cmp::Eq => 0.0,
            };
        }
        let mut rhs_scale = 1.0f64;
        for (r, row) in rows.iter_mut().enumerate() {
            if rhs[r] < 0.0 {
                rhs[r] = -rhs[r];
                slack_sign[r] = -slack_sign[r];
                for v in row.iter_mut() {
                    *v = -*v;
                }
            }
            rhs_scale = rhs_scale.max(rhs[r].abs());
        }

        // column plan: a slack per inequality; an artificial wherever the
        // slack cannot serve as the initial basic variable
        let ns = slack_sign.iter().filter(|&&s| s != 0.0).count();
        let needs_art: Vec<bool> =
            slack_sign.iter().map(|&s| s != 1.0).collect();
        let na = needs_art.iter().filter(|&&b| b).count();
        let art0 = nf + ns;
        let n = art0 + na;

        let mut a = vec![vec![0.0; n + 1]; m];
        let mut basis = vec![usize::MAX; m];
        let mut in_basis = vec![false; n];
        let mut ub = vec![f64::INFINITY; n];
        for (k, &j) in free.iter().enumerate() {
            ub[k] = upper[j] - lower[j];
        }
        let mut next_slack = nf;
        let mut next_art = art0;
        for r in 0..m {
            a[r][..nf].copy_from_slice(&rows[r]);
            a[r][n] = rhs[r];
            if slack_sign[r] != 0.0 {
                a[r][next_slack] = slack_sign[r];
                if slack_sign[r] == 1.0 {
                    basis[r] = next_slack;
                }
                next_slack += 1;
            }
            if needs_art[r] {
                a[r][next_art] = 1.0;
                basis[r] = next_art;
                next_art += 1;
            }
            in_basis[basis[r]] = true;
        }

        let obj_base: f64 = (0..nv)
            .map(|j| {
                p.objective[j]
                    * if col_of[j] == usize::MAX {
                        fixed_val[j]
                    } else {
                        lower[j]
                    }
            })
            .sum();

        Ok(Simplex {
            p,
            lower,
            free,
            fixed_val,
            m,
            n,
            art0,
            a,
            z: vec![0.0; n + 1],
            basis,
            in_basis,
            ub,
            flipped: vec![false; n],
            obj_base,
            rhs_scale,
        })
    }

    /// Complement-flip a nonbasic column: `x := ub - x`.
    fn flip(&mut self, j: usize) {
        let u = self.ub[j];
        for r in 0..self.m {
            let arj = self.a[r][j];
            if arj != 0.0 {
                self.a[r][self.n] -= arj * u;
                self.a[r][j] = -arj;
            }
        }
        self.z[self.n] -= self.z[j] * u;
        self.z[j] = -self.z[j];
        self.flipped[j] = !self.flipped[j];
    }

    fn pivot(&mut self, r: usize, j: usize) {
        let inv = 1.0 / self.a[r][j];
        for v in self.a[r].iter_mut() {
            *v *= inv;
        }
        self.a[r][j] = 1.0;
        for i in 0..self.m {
            if i == r {
                continue;
            }
            let f = self.a[i][j];
            if f != 0.0 {
                for c in 0..=self.n {
                    self.a[i][c] -= f * self.a[r][c];
                }
                self.a[i][j] = 0.0;
                // roundoff must not leave a basic value slightly negative
                if self.a[i][self.n] < 0.0 && self.a[i][self.n] > -1e-7 {
                    self.a[i][self.n] = 0.0;
                }
            }
        }
        let f = self.z[j];
        if f != 0.0 {
            for c in 0..=self.n {
                self.z[c] -= f * self.a[r][c];
            }
            self.z[j] = 0.0;
        }
        self.in_basis[self.basis[r]] = false;
        self.in_basis[j] = true;
        self.basis[r] = j;
    }

    /// Price and pivot until optimal. `allow_art` admits artificial
    /// columns as entering candidates (phase 1 never needs it either —
    /// artificials start basic and must not re-enter once driven out).
    fn optimize(&mut self) -> LpStatus {
        let max_iters = 200 * (self.m + self.n) + 2000;
        let bland_after = 50 * (self.m + self.n) + 500;
        for it in 0..max_iters {
            let bland = it > bland_after;
            // entering column
            let mut enter = None;
            let mut best = -EPS;
            for j in 0..self.n {
                if self.in_basis[j] || j >= self.art0 {
                    continue;
                }
                let zj = self.z[j];
                if zj < -EPS {
                    if bland {
                        enter = Some(j);
                        break;
                    }
                    if zj < best {
                        best = zj;
                        enter = Some(j);
                    }
                }
            }
            let Some(j) = enter else {
                return LpStatus::Optimal;
            };

            // ratio test: basic leaves at lower, basic reaches its upper,
            // or the entering column hits its own bound (a pure flip)
            let mut t = self.ub[j];
            let mut leave: Option<(usize, bool)> = None;
            for r in 0..self.m {
                let arj = self.a[r][j];
                if arj > EPS {
                    let tr = (self.a[r][self.n] / arj).max(0.0);
                    if tr < t - 1e-12
                        || (bland
                            && leave.is_some()
                            && tr < t + 1e-12
                            && self.basis[r]
                                < self.basis[leave.unwrap().0])
                    {
                        t = tr.min(t);
                        leave = Some((r, false));
                    }
                } else if arj < -EPS {
                    let ubr = self.ub[self.basis[r]];
                    if ubr.is_finite() {
                        let tr =
                            ((ubr - self.a[r][self.n]) / -arj).max(0.0);
                        if tr < t - 1e-12
                            || (bland
                                && leave.is_some()
                                && tr < t + 1e-12
                                && self.basis[r]
                                    < self.basis[leave.unwrap().0])
                        {
                            t = tr.min(t);
                            leave = Some((r, true));
                        }
                    }
                }
            }
            match leave {
                None if t.is_infinite() => return LpStatus::Unbounded,
                None => self.flip(j), // entering var runs to its bound
                Some((r, at_upper)) => {
                    if at_upper {
                        // leaving var exits at its upper bound: flip its
                        // (unit) column first so it leaves at zero
                        let k = self.basis[r];
                        self.a[r][self.n] -= self.ub[k];
                        self.a[r][k] = -1.0;
                        self.flipped[k] = !self.flipped[k];
                    }
                    self.pivot(r, j);
                }
            }
        }
        LpStatus::IterLimit
    }

    fn extract(&self) -> Vec<f64> {
        let mut val = vec![0.0; self.n];
        for r in 0..self.m {
            val[self.basis[r]] = self.a[r][self.n];
        }
        let mut x = self.fixed_val.clone();
        for (k, &j) in self.free.iter().enumerate() {
            let v = if self.flipped[k] {
                self.ub[k] - val[k]
            } else {
                val[k]
            };
            x[j] = self.lower[j] + v;
        }
        x
    }

    fn run(&mut self) -> LpSolution {
        let fail = |status| LpSolution {
            status,
            x: Vec::new(),
            objective: f64::INFINITY,
        };
        // ---- phase 1: minimize the sum of artificials ----
        if self.art0 < self.n {
            // z := -(sum of artificial rows), pricing out the basis
            for r in 0..self.m {
                if self.basis[r] >= self.art0 {
                    for c in 0..=self.n {
                        self.z[c] -= self.a[r][c];
                    }
                    self.z[self.basis[r]] = 0.0;
                }
            }
            // (artificial columns carry cost 1; they are excluded from
            // entering, so their reduced costs never matter)
            match self.optimize() {
                LpStatus::Optimal => {}
                s => return fail(s),
            }
            if -self.z[self.n] > FEAS_TOL * (1.0 + self.rhs_scale) {
                return fail(LpStatus::Infeasible);
            }
            // drive surviving artificials out of the basis; a row with no
            // eligible pivot is linearly dependent — drop it
            let mut r = 0;
            while r < self.m {
                if self.basis[r] < self.art0 {
                    r += 1;
                    continue;
                }
                let piv = (0..self.art0).find(|&j| {
                    !self.in_basis[j] && self.a[r][j].abs() > 1e-7
                });
                match piv {
                    Some(j) => {
                        self.pivot(r, j);
                        r += 1;
                    }
                    None => {
                        self.in_basis[self.basis[r]] = false;
                        self.a.swap_remove(r);
                        self.basis.swap_remove(r);
                        self.m -= 1;
                    }
                }
            }
        }

        // ---- phase 2: the real objective ----
        self.z = vec![0.0; self.n + 1];
        for (k, &j) in self.free.iter().enumerate() {
            let c = self.p.objective[j];
            if self.flipped[k] {
                self.z[k] = -c;
                self.z[self.n] -= c * self.ub[k];
            } else {
                self.z[k] = c;
            }
        }
        for r in 0..self.m {
            let k = self.basis[r];
            let f = self.z[k];
            if f != 0.0 {
                for c in 0..=self.n {
                    self.z[c] -= f * self.a[r][c];
                }
                self.z[k] = 0.0;
            }
        }
        let status = self.optimize();
        match status {
            LpStatus::Optimal | LpStatus::IterLimit => LpSolution {
                status,
                x: self.extract(),
                objective: self.obj_base - self.z[self.n],
            },
            s => fail(s),
        }
    }
}

// --------------------------- branch-and-bound ---------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MilpStatus {
    /// Incumbent proven optimal (within `abs_gap`).
    Optimal,
    /// Incumbent feasible but the search stopped early (time/node
    /// budget, or an LP hit its iteration cap).
    Feasible,
    Infeasible,
    Unbounded,
    /// Search stopped early with no incumbent found.
    Limit,
    /// Refused up front: the dense tableau would exceed `max_cells`.
    TooLarge,
}

#[derive(Clone, Debug)]
pub struct MilpSolution {
    pub status: MilpStatus,
    /// Best integral solution found (the warm start if nothing better).
    pub x: Vec<f64>,
    pub objective: f64,
    /// Best proven lower bound on the optimum.
    pub bound: f64,
    /// Branch-and-bound nodes whose LP was solved.
    pub nodes: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct MilpOpts {
    /// Wall-clock budget; `None` = unlimited.
    pub time_budget: Option<Duration>,
    pub max_nodes: usize,
    /// Cap on `rows * columns` of the dense tableau.
    pub max_cells: usize,
    /// An incumbent within this of the best bound counts as optimal.
    pub abs_gap: f64,
}

impl Default for MilpOpts {
    fn default() -> Self {
        MilpOpts {
            time_budget: None,
            max_nodes: 100_000,
            max_cells: 16_000_000,
            abs_gap: 1e-9,
        }
    }
}

/// Heap entry ordered so the *smallest* bound pops first (best-bound).
struct Entry {
    bound: f64,
    id: u64,
    fixes: Vec<(usize, f64)>,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.id == other.id
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want min-bound first
        other
            .bound
            .total_cmp(&self.bound)
            .then(other.id.cmp(&self.id))
    }
}

/// Branch-and-bound over the problem's binary variables. `warm` seeds
/// the incumbent (it is verified feasible first); the result is never
/// worse than a feasible warm start.
pub fn solve(
    p: &Problem,
    opts: &MilpOpts,
    warm: Option<&[f64]>,
) -> MilpSolution {
    let deadline = opts.time_budget.map(|d| Instant::now() + d);
    let mut inc: Option<(Vec<f64>, f64)> = warm.and_then(|w| {
        p.is_feasible(w, 10.0 * FEAS_TOL)
            .then(|| (w.to_vec(), p.eval(w)))
    });

    let m = p.constraints.len();
    let est_cols = p.num_vars() + 2 * m;
    let finish = |status: MilpStatus,
                  inc: Option<(Vec<f64>, f64)>,
                  bound: f64,
                  nodes: usize| {
        match inc {
            Some((x, obj)) => MilpSolution {
                status,
                x,
                objective: obj,
                bound,
                nodes,
            },
            None => MilpSolution {
                status,
                x: Vec::new(),
                objective: f64::INFINITY,
                bound,
                nodes,
            },
        }
    };
    if m.saturating_mul(est_cols + 1) > opts.max_cells {
        let st = MilpStatus::TooLarge;
        return finish(st, inc, f64::NEG_INFINITY, 0);
    }

    let mut lower = p.lower.clone();
    let mut upper = p.upper.clone();
    let mut heap = BinaryHeap::new();
    let mut next_id = 0u64;
    heap.push(Entry {
        bound: f64::NEG_INFINITY,
        id: 0,
        fixes: Vec::new(),
    });
    let mut nodes = 0usize;
    let mut best_bound = f64::NEG_INFINITY;
    // true once any subtree was dropped unexplored (LP iteration cap):
    // optimality/infeasibility can no longer be claimed
    let mut incomplete = false;

    while let Some(node) = heap.pop() {
        best_bound = best_bound.max(node.bound);
        if let Some((_, iobj)) = &inc {
            if node.bound >= iobj - opts.abs_gap {
                // best-bound order: every open node is at least this bad
                return finish(MilpStatus::Optimal, inc, *iobj, nodes);
            }
        }
        if nodes >= opts.max_nodes
            || deadline.map(|d| Instant::now() >= d).unwrap_or(false)
        {
            let st = if inc.is_some() {
                MilpStatus::Feasible
            } else {
                MilpStatus::Limit
            };
            return finish(st, inc, node.bound.max(best_bound), nodes);
        }
        nodes += 1;

        for &(j, v) in &node.fixes {
            lower[j] = v;
            upper[j] = v;
        }
        let lp = solve_lp_bounds(p, &lower, &upper);
        for &(j, _) in &node.fixes {
            lower[j] = p.lower[j];
            upper[j] = p.upper[j];
        }

        match lp.status {
            LpStatus::Infeasible => continue,
            LpStatus::Unbounded => {
                // a relaxation is unbounded in its continuous vars, so
                // the restricted integer problem is too
                return MilpSolution {
                    status: MilpStatus::Unbounded,
                    x: Vec::new(),
                    objective: f64::NEG_INFINITY,
                    bound: f64::NEG_INFINITY,
                    nodes,
                };
            }
            LpStatus::IterLimit => {
                incomplete = true;
                continue;
            }
            LpStatus::Optimal => {}
        }
        if let Some((_, iobj)) = &inc {
            if lp.objective >= iobj - opts.abs_gap {
                continue;
            }
        }

        // most fractional binary
        let mut branch = None;
        let mut best_frac = INT_TOL;
        for j in 0..p.num_vars() {
            if !p.binary[j] {
                continue;
            }
            let f = (lp.x[j] - lp.x[j].round()).abs();
            if f > best_frac {
                best_frac = f;
                branch = Some(j);
            }
        }
        match branch {
            None => {
                // integral on every binary: snap and take as incumbent
                let mut x = lp.x.clone();
                for j in 0..p.num_vars() {
                    if p.binary[j] {
                        x[j] = x[j].round();
                    }
                }
                let obj = p.eval(&x);
                if inc.as_ref().map(|(_, io)| obj < *io).unwrap_or(true) {
                    inc = Some((x, obj));
                }
            }
            Some(j) => {
                for v in [0.0, 1.0] {
                    let mut fixes = node.fixes.clone();
                    fixes.push((j, v));
                    next_id += 1;
                    heap.push(Entry {
                        bound: lp.objective,
                        id: next_id,
                        fixes,
                    });
                }
            }
        }
    }

    // heap drained
    match (&inc, incomplete) {
        (Some((_, obj)), false) => {
            let obj = *obj;
            finish(MilpStatus::Optimal, inc, obj, nodes)
        }
        (Some(_), true) => {
            finish(MilpStatus::Feasible, inc, best_bound, nodes)
        }
        (None, false) => {
            finish(MilpStatus::Infeasible, inc, f64::INFINITY, nodes)
        }
        (None, true) => finish(MilpStatus::Limit, inc, best_bound, nodes),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_near(a: f64, b: f64) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }

    #[test]
    fn lp_bounds_and_row() {
        // max x + y  s.t.  x + y <= 4, x in [0,2], y in [0,3]
        let mut p = Problem::new();
        let x = p.add_var(-1.0, 0.0, 2.0);
        let y = p.add_var(-1.0, 0.0, 3.0);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 4.0);
        let s = solve_lp(&p);
        assert_eq!(s.status, LpStatus::Optimal);
        assert_near(s.objective, -4.0);
        assert_near(s.x[x] + s.x[y], 4.0);
    }

    #[test]
    fn lp_degenerate_vertex() {
        // three rows tight at (1, 1) in 2D
        let mut p = Problem::new();
        let x = p.add_var(-1.0, 0.0, 10.0);
        let y = p.add_var(-1.0, 0.0, 10.0);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Le, 2.0);
        p.constrain(vec![(x, 1.0)], Cmp::Le, 1.0);
        p.constrain(vec![(y, 1.0)], Cmp::Le, 1.0);
        let s = solve_lp(&p);
        assert_eq!(s.status, LpStatus::Optimal);
        assert_near(s.objective, -2.0);
    }

    #[test]
    fn lp_unbounded() {
        let mut p = Problem::new();
        let _x = p.add_var(-1.0, 0.0, f64::INFINITY);
        let y = p.add_var(0.0, 0.0, f64::INFINITY);
        p.constrain(vec![(y, 1.0)], Cmp::Le, 5.0);
        assert_eq!(solve_lp(&p).status, LpStatus::Unbounded);
    }

    #[test]
    fn lp_infeasible() {
        let mut p = Problem::new();
        let x = p.add_var(1.0, 0.0, 1.0);
        p.constrain(vec![(x, 1.0)], Cmp::Ge, 2.0);
        assert_eq!(solve_lp(&p).status, LpStatus::Infeasible);
    }

    #[test]
    fn lp_equalities_and_negative_bounds() {
        let mut p = Problem::new();
        let x = p.add_var(1.0, -10.0, 10.0);
        let y = p.add_var(1.0, -10.0, 10.0);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Eq, 3.0);
        p.constrain(vec![(x, 1.0), (y, -1.0)], Cmp::Eq, 1.0);
        let s = solve_lp(&p);
        assert_eq!(s.status, LpStatus::Optimal);
        assert_near(s.x[x], 2.0);
        assert_near(s.x[y], 1.0);
        assert_near(s.objective, 3.0);
    }

    #[test]
    fn lp_surplus_rows() {
        // min x + y  s.t.  x + 2y >= 4, 3x + y >= 6
        let mut p = Problem::new();
        let x = p.add_var(1.0, 0.0, f64::INFINITY);
        let y = p.add_var(1.0, 0.0, f64::INFINITY);
        p.constrain(vec![(x, 1.0), (y, 2.0)], Cmp::Ge, 4.0);
        p.constrain(vec![(x, 3.0), (y, 1.0)], Cmp::Ge, 6.0);
        let s = solve_lp(&p);
        assert_eq!(s.status, LpStatus::Optimal);
        assert_near(s.objective, 2.8);
    }

    fn knapsack(v: &[f64], w: &[f64], cap: f64) -> Problem {
        let mut p = Problem::new();
        let terms = (0..v.len())
            .map(|i| {
                let j = p.add_binary(-v[i]);
                (j, w[i])
            })
            .collect();
        p.constrain(terms, Cmp::Le, cap);
        p
    }

    #[test]
    fn knapsack_hand_checked() {
        // classic: optimum picks items 2+3 for value 220
        let p = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        let s = solve(&p, &MilpOpts::default(), None);
        assert_eq!(s.status, MilpStatus::Optimal);
        assert_near(s.objective, -220.0);
        assert_near(s.x[0], 0.0);
        assert_near(s.x[1], 1.0);
        assert_near(s.x[2], 1.0);
        // the LP relaxation is fractional (bound -240), so the optimum
        // must come from actual branching
        assert!(s.nodes > 1, "expected branching, got {} node(s)", s.nodes);
    }

    #[test]
    fn knapsack_four_items() {
        // best is items 2+4: weight 7, value 90
        let p = knapsack(
            &[10.0, 40.0, 30.0, 50.0],
            &[5.0, 4.0, 6.0, 3.0],
            10.0,
        );
        let s = solve(&p, &MilpOpts::default(), None);
        assert_eq!(s.status, MilpStatus::Optimal);
        assert_near(s.objective, -90.0);
    }

    #[test]
    fn milp_infeasible() {
        let mut p = Problem::new();
        let x = p.add_binary(1.0);
        let y = p.add_binary(1.0);
        p.constrain(vec![(x, 1.0), (y, 1.0)], Cmp::Ge, 3.0);
        let s = solve(&p, &MilpOpts::default(), None);
        assert_eq!(s.status, MilpStatus::Infeasible);
    }

    #[test]
    fn warm_start_never_worsens() {
        let p = knapsack(&[60.0, 100.0, 120.0], &[10.0, 20.0, 30.0], 50.0);
        let warm = [1.0, 0.0, 0.0]; // value 60, feasible
        // zero search budget: the warm incumbent comes straight back
        let opts = MilpOpts { max_nodes: 0, ..Default::default() };
        let s = solve(&p, &opts, Some(&warm));
        assert_eq!(s.status, MilpStatus::Feasible);
        assert_near(s.objective, -60.0);
        assert_eq!(s.x, warm.to_vec());
        // full search can only improve on it
        let s = solve(&p, &MilpOpts::default(), Some(&warm));
        assert!(s.objective <= -60.0 + 1e-9);
        assert_near(s.objective, -220.0);
    }

    #[test]
    fn equality_over_binaries() {
        // pick exactly two of three, cheapest pair
        let mut p = Problem::new();
        let a = p.add_binary(1.0);
        let b = p.add_binary(2.0);
        let c = p.add_binary(3.0);
        p.constrain(
            vec![(a, 1.0), (b, 1.0), (c, 1.0)],
            Cmp::Eq,
            2.0,
        );
        let s = solve(&p, &MilpOpts::default(), None);
        assert_eq!(s.status, MilpStatus::Optimal);
        assert_near(s.objective, 3.0);
        assert_near(s.x[a], 1.0);
        assert_near(s.x[b], 1.0);
        assert_near(s.x[c], 0.0);
    }

    #[test]
    fn too_large_is_refused_but_keeps_warm() {
        let mut p = Problem::new();
        let vars: Vec<usize> =
            (0..100).map(|_| p.add_binary(-1.0)).collect();
        for &v in &vars {
            p.constrain(vec![(v, 1.0)], Cmp::Le, 1.0);
        }
        let warm = vec![1.0; 100];
        let opts = MilpOpts { max_cells: 10, ..Default::default() };
        let s = solve(&p, &opts, Some(&warm));
        assert_eq!(s.status, MilpStatus::TooLarge);
        assert_near(s.objective, -100.0);
        assert_eq!(s.x, warm);
    }
}
