//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the surface the repo actually uses: `Error`, `Result`,
//! `anyhow!`, `bail!`, `ensure!`, and the `Context` extension trait.
//! Semantics match upstream for that subset: `Error` is a cheap opaque
//! wrapper, any `std::error::Error` converts into it via `?`, and
//! `context(...)` prefixes the message while keeping the source chain.

use std::error::Error as StdError;
use std::fmt;

/// Opaque error type. Like upstream `anyhow::Error`, it deliberately does
/// NOT implement `std::error::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap with higher-level context (upstream `Error::context`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The lowest-level message in the chain, for diagnostics.
    pub fn root_cause(&self) -> String {
        match &self.source {
            Some(s) => {
                let mut cur: &(dyn StdError + 'static) = s.as_ref();
                while let Some(next) = cur.source() {
                    cur = next;
                }
                cur.to_string()
            }
            None => self.msg.clone(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if let Some(s) = &self.source {
            write!(f, "\n\nCaused by:\n    {s}")?;
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`, mirroring upstream.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/7f3a")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_prefixes_message() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 7");
    }

    #[test]
    fn ensure_and_bail_formats() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
    }

    #[test]
    fn option_context() {
        let v: Option<usize> = None;
        assert_eq!(
            v.context("missing value").unwrap_err().to_string(),
            "missing value"
        );
    }
}
