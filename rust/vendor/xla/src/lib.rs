//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The build environment has no crates.io access and no PJRT plugin, so
//! this vendored shim provides exactly the API surface
//! `automap::runtime` uses, with faithful *host-side* semantics
//! ([`Literal`] really stores and reshapes data) and a runtime error at
//! the hardware boundary: [`PjRtClient::cpu`] reports that no PJRT
//! backend is available. Everything that needs a live client
//! (`automap train`, `tp-check`, the artifact integration tests) fails
//! gracefully or skips; everything else — the entire planning, solving,
//! and simulation stack — builds and runs.
//!
//! Swap this path dependency for the real `xla` crate to run on actual
//! PJRT devices; no call-site changes are needed.

use std::fmt;

/// Error type matching the call sites' `{e:?}` formatting.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT unavailable: this build uses the offline `xla` stub \
         (rust/vendor/xla); install the real xla-rs bindings to execute \
         artifacts"
            .to_string(),
    ))
}

/// Element dtypes of the PJRT boundary (subset + the common extras so
/// caller `match` arms keep a reachable catch-all).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    F16,
    F32,
    F64,
    Bf16,
}

#[derive(Debug, Clone)]
enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal: fully functional (store, reshape, tuple, extract)
/// — only *execution* needs real PJRT.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    store: Store,
}

/// Rust scalar types that cross the literal boundary.
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn store(v: &[Self]) -> Store;
    fn unstore(s: &Store) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn store(v: &[Self]) -> Store {
        Store::F32(v.to_vec())
    }

    fn unstore(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn store(v: &[Self]) -> Store {
        Store::I32(v.to_vec())
    }

    fn unstore(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], store: T::store(v) }
    }

    /// Tuple literal (what executables return with `return_tuple=True`).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: vec![parts.len() as i64], store: Store::Tuple(parts) }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), store: self.store.clone() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        match &self.store {
            Store::F32(_) => Ok(ElementType::F32),
            Store::I32(_) => Ok(ElementType::S32),
            Store::Tuple(_) => {
                Err(Error("tuple literal has no element type".into()))
            }
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unstore(&self.store).ok_or_else(|| {
            Error(format!(
                "literal holds {:?}, not {:?}",
                self.ty(),
                T::TY
            ))
        })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.store {
            Store::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Synchronous host fetch (identity here: data already lives host-side).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.clone())
    }
}

/// Parsed HLO module text. The stub keeps the raw text; only a real PJRT
/// compiler consumes it.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// Device-side buffer handle returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// PJRT executable handle. Unreachable through the stub (no client can
/// be constructed), but fully typed so callers compile unchanged.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's hard boundary.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_host_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
        let t = Literal::tuple(vec![l.clone()]);
        assert_eq!(t.to_tuple().unwrap().len(), 1);
        assert!(t.ty().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("stub"));
    }
}
