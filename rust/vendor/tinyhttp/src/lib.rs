//! Minimal std-only HTTP/1.1 primitives for the `automap serve` daemon.
//!
//! Scope: exactly what a loopback planning daemon needs — request parsing
//! (request line, headers, `Content-Length` bodies), response writing,
//! chunked transfer-encoding (server-side writer and client-side decoder),
//! and a tiny blocking client over `TcpStream`. No TLS, no HTTP/2, no
//! keep-alive: every exchange is one request, one response, connection
//! close. Hyper/reqwest are unavailable offline; this crate keeps the
//! wire format honest from both sides without external dependencies.

use std::fmt;
use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Errors from parsing or transport. Wraps `io::Error` so `?` works in
/// handler code; protocol violations carry a short description.
#[derive(Debug)]
pub enum Error {
    Io(io::Error),
    Protocol(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "http io error: {e}"),
            Error::Protocol(m) => write!(f, "http protocol error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<io::Error> for Error {
    fn from(e: io::Error) -> Error {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn proto(msg: impl Into<String>) -> Error {
    Error::Protocol(msg.into())
}

/// Cap on header-section and body sizes, a guard against malformed or
/// hostile peers tying up a handler thread (plans are a few hundred KB).
pub const MAX_HEADER_BYTES: usize = 64 * 1024;
pub const MAX_BODY_BYTES: usize = 256 * 1024 * 1024;

/// A parsed HTTP/1.1 request. Header names are lowercased on parse.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First header value for `name` (case-insensitive), if present.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse one request from a buffered stream: request line, headers,
    /// then a `Content-Length` body (chunked request bodies are not
    /// accepted — the daemon's clients never send them).
    pub fn read_from<R: BufRead>(r: &mut R) -> Result<Request> {
        let line = read_line(r)?;
        let mut parts = line.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| proto("empty request line"))?
            .to_string();
        let path = parts
            .next()
            .ok_or_else(|| proto("request line missing path"))?
            .to_string();
        let version = parts.next().unwrap_or("HTTP/1.0");
        if !version.starts_with("HTTP/1.") {
            return Err(proto(format!("unsupported version '{version}'")));
        }
        let headers = read_headers(r)?;
        let mut req = Request {
            method,
            path,
            headers,
            body: Vec::new(),
        };
        if let Some(len) = req.header("content-length") {
            let len: usize = len
                .trim()
                .parse()
                .map_err(|_| proto(format!("bad content-length '{len}'")))?;
            if len > MAX_BODY_BYTES {
                return Err(proto(format!("body of {len} bytes exceeds cap")));
            }
            let mut body = vec![0u8; len];
            r.read_exact(&mut body)?;
            req.body = body;
        } else if req
            .header("transfer-encoding")
            .map(|v| v.eq_ignore_ascii_case("chunked"))
            .unwrap_or(false)
        {
            return Err(proto("chunked request bodies are not supported"));
        }
        Ok(req)
    }
}

/// An HTTP/1.1 response under construction. `Content-Length` and
/// `Connection: close` are added automatically on write.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    pub fn header(mut self, name: &str, value: &str) -> Response {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    pub fn body(mut self, bytes: impl Into<Vec<u8>>) -> Response {
        self.body = bytes.into();
        self
    }

    pub fn json(body: impl Into<Vec<u8>>, status: u16) -> Response {
        Response::new(status)
            .header("content-type", "application/json")
            .body(body)
    }

    /// Write status line, headers, and body; flushes the stream.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\n",
            self.status,
            status_text(self.status)
        )?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        write!(w, "content-length: {}\r\n", self.body.len())?;
        write!(w, "connection: close\r\n\r\n")?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok(())
    }
}

/// Canonical reason phrases for the handful of codes the daemon emits.
pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        _ => "Unknown",
    }
}

/// Server-side chunked transfer-encoding writer: send the header once,
/// then any number of chunks, then `finish()` for the zero-length
/// terminator. Each chunk is flushed immediately so clients observe
/// events as they happen.
pub struct ChunkedWriter<W: Write> {
    w: W,
    started: bool,
    status: u16,
    headers: Vec<(String, String)>,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn new(w: W, status: u16) -> ChunkedWriter<W> {
        ChunkedWriter {
            w,
            started: false,
            status,
            headers: Vec::new(),
        }
    }

    pub fn header(mut self, name: &str, value: &str) -> ChunkedWriter<W> {
        self.headers.push((name.to_string(), value.to_string()));
        self
    }

    fn start(&mut self) -> Result<()> {
        if self.started {
            return Ok(());
        }
        write!(
            self.w,
            "HTTP/1.1 {} {}\r\n",
            self.status,
            status_text(self.status)
        )?;
        for (k, v) in &self.headers {
            write!(self.w, "{k}: {v}\r\n")?;
        }
        write!(self.w, "transfer-encoding: chunked\r\n")?;
        write!(self.w, "connection: close\r\n\r\n")?;
        self.w.flush()?;
        self.started = true;
        Ok(())
    }

    /// Emit one chunk (empty input is skipped: a zero-length chunk is
    /// the stream terminator in the chunked coding).
    pub fn chunk(&mut self, data: &[u8]) -> Result<()> {
        self.start()?;
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        write!(self.w, "\r\n")?;
        self.w.flush()?;
        Ok(())
    }

    /// Terminate the stream with the zero-length chunk.
    pub fn finish(mut self) -> Result<()> {
        self.start()?;
        write!(self.w, "0\r\n\r\n")?;
        self.w.flush()?;
        Ok(())
    }
}

/// A client-side response: status, headers, and a reader positioned at
/// the start of the body. `read_body` drains it honoring
/// `Content-Length` / chunked / read-to-EOF; `next_chunk` steps a
/// chunked stream incrementally.
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    reader: BufReader<TcpStream>,
    chunked: bool,
    content_length: Option<usize>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Read the entire body.
    pub fn read_body(mut self) -> Result<Vec<u8>> {
        if self.chunked {
            let mut out = Vec::new();
            while let Some(chunk) = self.next_chunk()? {
                out.extend_from_slice(&chunk);
            }
            return Ok(out);
        }
        let mut out = Vec::new();
        match self.content_length {
            Some(len) => {
                if len > MAX_BODY_BYTES {
                    return Err(proto(format!("body of {len} bytes exceeds cap")));
                }
                out.resize(len, 0);
                self.reader.read_exact(&mut out)?;
            }
            None => {
                self.reader.read_to_end(&mut out)?;
            }
        }
        Ok(out)
    }

    /// Next chunk of a chunked body, or `None` after the terminator.
    /// Errors if the response is not chunked.
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        if !self.chunked {
            return Err(proto("response body is not chunked"));
        }
        let size_line = read_line(&mut self.reader)?;
        let size_str = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_str, 16)
            .map_err(|_| proto(format!("bad chunk size '{size_line}'")))?;
        if size > MAX_BODY_BYTES {
            return Err(proto(format!("chunk of {size} bytes exceeds cap")));
        }
        if size == 0 {
            // trailer section: zero or more header lines then a blank
            loop {
                if read_line(&mut self.reader)?.is_empty() {
                    break;
                }
            }
            return Ok(None);
        }
        let mut data = vec![0u8; size];
        self.reader.read_exact(&mut data)?;
        let mut crlf = [0u8; 2];
        self.reader.read_exact(&mut crlf)?;
        if &crlf != b"\r\n" {
            return Err(proto("chunk not terminated by CRLF"));
        }
        Ok(Some(data))
    }
}

/// Issue one blocking request against `addr` ("host:port") and parse the
/// response head. The connection closes after the exchange.
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<ClientResponse> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| proto(format!("connect to {addr} failed: {e}")))?;
    let mut w = stream.try_clone()?;
    write!(w, "{method} {path} HTTP/1.1\r\n")?;
    write!(w, "host: {addr}\r\n")?;
    for (k, v) in headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "content-length: {}\r\n", body.len())?;
    write!(w, "connection: close\r\n\r\n")?;
    w.write_all(body)?;
    w.flush()?;

    let mut reader = BufReader::new(stream);
    let status_line = read_line(&mut reader)?;
    let mut parts = status_line.split_whitespace();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(proto(format!("bad status line '{status_line}'")));
    }
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| proto(format!("bad status line '{status_line}'")))?;
    let headers = read_headers(&mut reader)?;
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.trim().parse().ok());
    Ok(ClientResponse {
        status,
        headers,
        reader,
        chunked,
        content_length,
    })
}

/// Read a CRLF- (or bare-LF-) terminated line, without the terminator.
fn read_line<R: BufRead>(r: &mut R) -> Result<String> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Err(proto("unexpected end of stream"));
    }
    if line.len() > MAX_HEADER_BYTES {
        return Err(proto("header line exceeds cap"));
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(line)
}

/// Read header lines until the blank separator; names are lowercased.
fn read_headers<R: BufRead>(r: &mut R) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    let mut total = 0usize;
    loop {
        let line = read_line(r)?;
        if line.is_empty() {
            return Ok(headers);
        }
        total += line.len();
        if total > MAX_HEADER_BYTES {
            return Err(proto("header section exceeds cap"));
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| proto(format!("malformed header '{line}'")))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/plan HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\nX-Automap-Tenant: t1\r\n\r\nhello";
        let mut r = BufReader::new(&raw[..]);
        let req = Request::read_from(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/plan");
        assert_eq!(req.header("x-automap-tenant"), Some("t1"));
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn response_roundtrips_headers_and_body() {
        let mut buf = Vec::new();
        Response::json(br#"{"ok":true}"#.to_vec(), 200)
            .write_to(&mut buf)
            .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("content-type: application/json\r\n"));
        assert!(text.contains("content-length: 11\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
    }

    #[test]
    fn chunked_writer_emits_sized_frames() {
        let mut buf = Vec::new();
        {
            let mut w = ChunkedWriter::new(&mut buf, 200)
                .header("content-type", "application/json");
            w.chunk(b"abc").unwrap();
            w.chunk(b"defgh").unwrap();
            w.finish().unwrap();
        }
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("transfer-encoding: chunked"));
        assert!(text.contains("3\r\nabc\r\n"));
        assert!(text.contains("5\r\ndefgh\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }

    #[test]
    fn rejects_oversized_content_length() {
        let raw = format!(
            "POST / HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        let mut r = BufReader::new(raw.as_bytes());
        assert!(Request::read_from(&mut r).is_err());
    }
}
